"""Ring attention (context parallelism) vs the bulk all-gather oracle on
8 devices: the managed collective (fwd + re-streamed backward ring), the
model-level schedule, the auto dispatcher's decision trail, and the
return_kv cache contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import managed
from repro.kernels import ref
from repro.models import attention
from repro.parallel.sharding import MeshCtx, smap


def _cfg(n_heads=8, n_kv_heads=2, hd=16, d=64, tp_multiple=8):
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=d,
                       n_heads=n_heads, n_kv_heads=n_kv_heads, d_ff=128,
                       vocab_size=128, d_head=hd, tp_multiple=tp_multiple)


@pytest.fixture(scope="module")
def mesh18():
    return jax.make_mesh((1, 8), ("data", "model"))


@pytest.fixture(scope="module")
def qkv():
    rng = np.random.default_rng(0)
    b, S, h, kvh, hd = 2, 256, 4, 2, 32
    return tuple(jnp.asarray(rng.normal(size=s).astype(np.float32))
                 for s in ((b, S, h, hd), (b, S, kvh, hd), (b, S, kvh, hd)))


# -- the managed collective ------------------------------------------------


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 70),
                                           (False, 0), (False, 70)])
@pytest.mark.parametrize("mode", ["bulk", "interleaved", "auto"])
def test_managed_ring_attention_vs_ref(mesh8, qkv, causal, window, mode):
    q, k, v = qkv
    fn = jax.jit(smap(
        lambda q_, k_, v_: managed.managed_ring_attention(
            q_, k_, v_, "x", causal, window, mode),
        mesh8, in_specs=(P(None, "x"),) * 3, out_specs=P(None, "x")))
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(fn(q, k, v)), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_managed_ring_attention_grads(mesh8, qkv, causal):
    """The re-streamed backward ring == bulk-mode grads == autodiff of the
    dense reference (dk/dv accumulators arrive home with the full sum)."""
    q, k, v = qkv
    rng = np.random.default_rng(1)
    dout = jnp.asarray(rng.normal(size=q.shape).astype(np.float32))

    def grads(mode):
        def f(q_, k_, v_, d_):
            o = managed.managed_ring_attention(q_, k_, v_, "x", causal, 0,
                                               mode)
            return jnp.sum(o * d_)
        return jax.jit(smap(jax.grad(f, argnums=(0, 1, 2)), mesh8,
                            in_specs=(P(None, "x"),) * 4,
                            out_specs=(P(None, "x"),) * 3))(q, k, v, dout)

    def fref(q_, k_, v_):
        return jnp.sum(ref.flash_attention_ref(q_, k_, v_, causal=causal)
                       * dout)

    want = jax.grad(fref, argnums=(0, 1, 2))(q, k, v)
    for mode in ("bulk", "interleaved"):
        for g, w, nm in zip(grads(mode), want, "qkv"):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=3e-4, atol=3e-5,
                                       err_msg=f"{mode} d{nm}")


# -- the model-level schedule ----------------------------------------------


def _run_attn(fn, mesh, cfg, ctx, x, params, **kw):
    pspecs = (P(None, "model"), P(None, None), P("model", None))

    def body(x_, wq, wkv, wo):
        return fn(x_, {"w_q": wq, "w_kv": wkv, "w_o": wo}, cfg, ctx, **kw)

    return np.asarray(jax.jit(smap(
        body, mesh, in_specs=(P(None, "model"),) + pspecs,
        out_specs=P(None, "model")))(
        x, params["w_q"], params["w_kv"], params["w_o"]))


@pytest.fixture(scope="module")
def attn_inputs():
    cfg = _cfg()
    rng = np.random.default_rng(2)
    b, S, d = 2, 128, cfg.d_model
    hp, hd = cfg.padded_heads, cfg.head_dim
    kvh = attention.padded_kv_heads(cfg)
    x = jnp.asarray(rng.normal(size=(b, S, d)).astype(np.float32) * 0.1)
    params = {
        "w_q": jnp.asarray(
            rng.normal(size=(d, hp * hd)).astype(np.float32) * 0.1),
        "w_kv": jnp.asarray(
            rng.normal(size=(d, 2 * kvh * hd)).astype(np.float32) * 0.1),
        "w_o": jnp.asarray(
            rng.normal(size=(hp * hd, d)).astype(np.float32) * 0.1),
    }
    return cfg, x, params


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 48),
                                           (False, 0)])
@pytest.mark.parametrize("mode", ["bulk", "interleaved"])
def test_attention_sp_ring_matches_sp(mesh18, attn_inputs, causal, window,
                                      mode):
    """attention_sp_ring == attention_sp (bulk oracle) on the 8-way model
    axis, causal and non-causal prefill, with GQA (8 q heads : 2 kv)."""
    cfg, x, params = attn_inputs
    want = _run_attn(attention.attention_sp, mesh18, cfg,
                     MeshCtx.from_mesh(mesh18, "bulk"), x, params,
                     causal=causal, window=window)
    got = _run_attn(attention.attention_sp_ring, mesh18, cfg,
                    MeshCtx.from_mesh(mesh18, mode), x, params,
                    causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


def test_attention_sp_ring_return_kv(mesh18, attn_inputs):
    """The prefill cache path: ring returns this rank's sequence slice
    with ALL kv heads — same contract as attention_sp/ulysses."""
    cfg, x, params = attn_inputs
    pspecs = (P(None, "model"), P(None, None), P("model", None))

    def run(fn, mode):
        def body(x_, wq, wkv, wo):
            y, (k, v) = fn(x_, {"w_q": wq, "w_kv": wkv, "w_o": wo}, cfg,
                           MeshCtx.from_mesh(mesh18, mode), causal=True,
                           return_kv=True)
            return y, k, v
        return [np.asarray(a) for a in jax.jit(smap(
            body, mesh18, in_specs=(P(None, "model"),) + pspecs,
            out_specs=(P(None, "model"),) * 3))(
            x, params["w_q"], params["w_kv"], params["w_o"])]

    y1, k1, v1 = run(attention.attention_sp, "bulk")
    y2, k2, v2 = run(attention.attention_sp_ring, "interleaved")
    np.testing.assert_allclose(y2, y1, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(k2, k1, rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(v2, v1, rtol=3e-4, atol=3e-5)


def test_auto_logs_decision_per_layer(mesh18, attn_inputs):
    """mode='auto' routes through resolve_attention_schedule and logs one
    decide_attention_schedule DecisionRecord per (unrolled) layer."""
    cfg, x, params = attn_inputs
    managed.clear_decision_log()
    ctx = MeshCtx.from_mesh(mesh18, "auto")
    want = _run_attn(attention.attention_sp, mesh18, cfg,
                     MeshCtx.from_mesh(mesh18, "bulk"), x, params,
                     causal=True)
    for _ in range(cfg.n_layers):
        got = _run_attn(attention.attention_sp_auto, mesh18, cfg, ctx, x,
                        params, causal=True)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)
    recs = [r for r in managed.decision_log()
            if r.op == "attention_schedule"]
    assert len(recs) >= cfg.n_layers
    assert all(r.mode in ("bulk", "ulysses", "ring") for r in recs)
    assert all(r.axis == "model" for r in recs)


def test_train_step_with_ring_attention():
    """End-to-end: a (2x2) train step with attn_impl='ring' (both comm
    modes) and 'auto' matches the megatron bulk baseline — the ring VJP
    composes with lax.scan, jax.checkpoint remat, and the FSDP gather
    transposes."""
    import dataclasses
    from repro import configs
    from repro.data.pipeline import DataConfig, SyntheticLMData
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.train_loop import build_train_step

    base = dataclasses.replace(configs.get_reduced("granite-34b"),
                               dtype="float32")

    def train_once(cfg, mode, params0, batch_np):
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        ctx = MeshCtx.from_mesh(mesh, mdmp_mode=mode)
        model = Model(cfg, ctx)
        step_fn, pshard, bshard = build_train_step(
            model, AdamWConfig(lr=1e-2), mesh, donate=False)
        params = jax.tree.map(
            lambda a, s: jax.device_put(np.asarray(a), s), params0, pshard)
        opt = adamw_init(params, AdamWConfig())
        batch = {kk: jax.device_put(vv, bshard[kk])
                 for kk, vv in batch_np.items()}
        p2, _, m = step_fn(params, opt, batch)
        return float(m["loss"]), jax.tree.map(np.asarray, p2)

    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    params0 = jax.tree.map(
        np.asarray, Model(base, MeshCtx.from_mesh(mesh1)).init(
            jax.random.key(0)))
    batch = SyntheticLMData(DataConfig(
        vocab_size=base.vocab_size, seq_len=32,
        global_batch=4)).global_batch_at(0)

    l_ref, p_ref = train_once(base, "bulk", params0, batch)
    for impl, mode in (("ring", "bulk"), ("ring", "interleaved"),
                       ("auto", "auto")):
        cfg = dataclasses.replace(base, attn_impl=impl)
        l, p = train_once(cfg, mode, params0, batch)
        np.testing.assert_allclose(l, l_ref, rtol=2e-4,
                                   err_msg=f"{impl} {mode}")
        for (k1, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(p_ref)[0],
                jax.tree_util.tree_flatten_with_path(p)[0]):
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=3e-4,
                                       err_msg=f"{impl} {mode} {k1}")


def test_forced_interleaved_resolves_to_ring():
    """The paper's always-intermingle mode pins the streaming schedule."""
    d = managed.resolve_attention_schedule(
        "model", 8, 1, 4096, 32, 8, 128, 4096, mode="interleaved")
    assert d.schedule == "ring"
    d = managed.resolve_attention_schedule(
        "model", 8, 1, 4096, 32, 8, 128, 4096, mode="bulk")
    assert d.schedule == "bulk"
