"""Distributed serving: the paged engine on the 8-device data x model
mesh — page pools sharded over the cache axes, per-rank paged partials
LSE-merged across the mesh (distributed flash-decoding), write-ownership
by page id.  Greedy tokens must match the single-device run and the
contiguous-cache oracle exactly."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.serve.engine import ServeEngine
from repro.train.serve_loop import Generator

ARCHS = ["granite-34b", "mamba2-130m"]


def _setup(arch, mesh_shape):
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32")
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    return cfg, mesh, model, params


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_serving_2x4_matches_1x1_and_oracle(arch):
    rng = np.random.default_rng(1)
    cfg0 = configs.get_reduced(arch)
    prompts = [rng.integers(0, cfg0.vocab_size - 1, size=p)
               .astype(np.int32) for p in (4, 7, 3, 9)]

    outs = {}
    for mesh_shape in [(1, 1), (2, 4)]:
        cfg, mesh, model, params = _setup(arch, mesh_shape)
        eng = ServeEngine(model, mesh, params, slots=2, max_seq=32,
                          page_size=4, schedule="continuous", chunk=4)
        rids = [eng.submit(p, 5) for p in prompts]
        res = eng.run()
        outs[mesh_shape] = [res[r] for r in rids]
        if mesh_shape == (1, 1):
            gen = Generator(model, mesh,
                            ShapeConfig("s", 32, 1, "decode"), params)
            for got, p in zip(outs[mesh_shape], prompts):
                want = gen.generate(p[None], n_new=5)[0]
                np.testing.assert_array_equal(got, want)
    for a, b in zip(outs[(2, 4)], outs[(1, 1)]):
        np.testing.assert_array_equal(a, b)


def test_paged_pool_sharding_covers_all_ranks():
    """The pool's page dim really is sharded over (data x model): with 8
    pages on the 2x4 mesh every rank owns exactly one page, so a decode
    touching 5 pages exercises cross-rank gathers + the ownership-gated
    write on most ranks."""
    cfg, mesh, model, params = _setup("granite-34b", (2, 4))
    eng = ServeEngine(model, mesh, params, slots=1, max_seq=32,
                      page_size=4, n_pages=8, schedule="static", chunk=8)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size - 1, size=14).astype(np.int32)
    rid = eng.submit(prompt, 5)
    res = eng.run()

    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    ctx1 = MeshCtx.from_mesh(mesh1, mdmp_mode="bulk")
    model1 = Model(dataclasses.replace(configs.get_reduced("granite-34b"),
                                       dtype="float32"), ctx1)
    params1 = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model1.init(jax.random.key(0)),
        infer_shardings(model1.param_specs(), mesh1))
    want = Generator(model1, mesh1, ShapeConfig("s", 32, 1, "decode"),
                     params1).generate(prompt[None], n_new=5)[0]
    np.testing.assert_array_equal(res[rid], want)
    assert eng.pt.high_water == 5        # ceil(19 / 4) pages were live
