"""Distributed model correctness: (1x1) == (2x2 bulk) == (2x2 interleaved)
== (2x2x2 multipod) for training steps and greedy decode, per family.

The full 10-arch sweep lives in scripts/validate_all.py; here three
representative families (dense+MQA, ssm, moe/ep_a2a) keep CI time sane.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.train.serve_loop import Generator
from repro.train.train_loop import build_train_step

ARCHS = ["granite-34b", "mamba2-130m", "moonshot-v1-16b-a3b"]


def _cfg(arch):
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return cfg


def _train_once(cfg, mesh_shape, axes, mode, params0, batch_np):
    mesh = jax.make_mesh(mesh_shape, axes)
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=mode)
    model = Model(cfg, ctx)
    step_fn, pshard, bshard = build_train_step(
        model, AdamWConfig(lr=1e-2), mesh, donate=False)
    params = jax.tree.map(lambda a, s: jax.device_put(np.asarray(a), s),
                          params0, pshard)
    opt = adamw_init(params, AdamWConfig())
    batch = {k: jax.device_put(v, bshard[k]) for k, v in batch_np.items()}
    p2, _, m = step_fn(params, opt, batch)
    return float(m["loss"]), jax.tree.map(np.asarray, p2)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_equivalence(arch):
    cfg = _cfg(arch)
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    model0 = Model(cfg, MeshCtx.from_mesh(mesh1))
    params0 = jax.tree.map(np.asarray, model0.init(jax.random.key(0)))
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=32, global_batch=4))
    batch = data.global_batch_at(0)

    l_ref, p_ref = _train_once(cfg, (1, 1), ("data", "model"), "bulk",
                               params0, batch)
    rtol = 1e-3 if cfg.moe is not None else 2e-4
    for shape, axes, mode in [((2, 2), ("data", "model"), "bulk"),
                              ((2, 2), ("data", "model"), "interleaved"),
                              ((2, 2, 2), ("pod", "data", "model"), "bulk")]:
        l, p = _train_once(cfg, shape, axes, mode, params0, batch)
        np.testing.assert_allclose(l, l_ref, rtol=rtol,
                                   err_msg=f"{arch} {shape} {mode}")
        for (k1, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(p_ref)[0],
                jax.tree_util.tree_flatten_with_path(p)[0]):
            np.testing.assert_allclose(
                a, b, rtol=2e-3, atol=3e-4,
                err_msg=f"{arch} {shape} {mode} {k1}")


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_equivalence(arch):
    cfg = _cfg(arch)
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="decode")
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size - 1, size=(4, 6)).astype(
        np.int32)
    outs = []
    for mesh_shape, axes in [((1, 1), ("data", "model")),
                             ((2, 2), ("data", "model")),
                             ((2, 2, 2), ("pod", "data", "model"))]:
        mesh = jax.make_mesh(mesh_shape, axes)
        ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
        model = Model(cfg, ctx)
        params = jax.tree.map(
            lambda a, s: jax.device_put(np.asarray(a), s),
            model.init(jax.random.key(0)),
            infer_shardings(model.param_specs(), mesh))
        gen = Generator(model, mesh, shape, params)
        outs.append(gen.generate(prompt, n_new=5))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_compressed_psum_error_feedback():
    """int8 cross-pod gradient compression: one-shot quantisation error is
    bounded, and error feedback pushes the BIAS of repeated compressions
    to zero (the residual carries over)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.parallel import compression
    from repro.parallel.sharding import smap

    mesh = jax.make_mesh((8,), ("x",))
    rng = np.random.default_rng(0)
    g = rng.normal(size=(1024,)).astype(np.float32)

    def once(grad, err):
        return compression.compressed_psum(grad, "x", err)

    f = jax.jit(smap(once, mesh, in_specs=(P(None), P(None)),
                     out_specs=(P(None), P(None))))
    total, err = f(jnp.asarray(g), jnp.zeros_like(g))
    want = g * 8
    rel = np.abs(np.asarray(total) - want).max() / np.abs(want).max()
    assert rel < 0.02    # one-shot int8 error ~ 1/127

    # error feedback: accumulated sum over steps converges to the truth
    acc = np.zeros_like(g)
    acc_exact = np.zeros_like(g)
    err = jnp.zeros_like(g)
    for _ in range(30):
        total, err = f(jnp.asarray(g), err)
        acc += np.asarray(total)
        acc_exact += want
    drift = np.abs(acc - acc_exact).max() / np.abs(acc_exact).max()
    assert drift < 0.002


def test_halo_jacobi_modes_match(mesh8):
    """The paper's running example: bulk (Fig 2) and overlapped (Fig 3)
    halo schedules produce identical sweeps."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import halo
    from repro.parallel.sharding import smap

    rng = np.random.default_rng(0)
    u0 = jnp.asarray(rng.normal(size=(64, 34)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(64, 34)).astype(np.float32))

    def solve(mode):
        def body(u, ff):
            return halo.jacobi_solve(u, ff, "x", iters=5, mode=mode)
        return np.asarray(jax.jit(smap(
            body, jax.make_mesh((8,), ("x",)),
            in_specs=(P("x"), P("x")), out_specs=P("x")))(u0, f))

    np.testing.assert_allclose(solve("bulk"), solve("interleaved"),
                               rtol=1e-6)


def test_halo_jacobi_matches_single_device():
    """Distributed sweeps == single-array reference (kernels/ref.py)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import halo
    from repro.kernels import ref
    from repro.parallel.sharding import smap

    rng = np.random.default_rng(1)
    u0 = rng.normal(size=(64, 34)).astype(np.float32)
    f = rng.normal(size=(64, 34)).astype(np.float32)

    def body(u, ff):
        return halo.jacobi_solve(u, ff, "x", iters=3, mode="bulk")

    dist = np.asarray(jax.jit(smap(
        body, jax.make_mesh((8,), ("x",)),
        in_specs=(P("x"), P("x")), out_specs=P("x")))(
        jnp.asarray(u0), jnp.asarray(f)))

    # single-array reference: pad with zero halos like MPI_PROC_NULL
    ref_u = np.pad(u0, ((1, 1), (0, 0)))
    ref_f = np.pad(f, ((1, 1), (0, 0)))
    for _ in range(3):
        new = ref.jacobi_step_ref(jnp.asarray(ref_u), jnp.asarray(ref_f))
        ref_u = np.array(new)          # writable copy
        ref_u[0] = 0.0
        ref_u[-1] = 0.0
    np.testing.assert_allclose(dist, ref_u[1:-1], rtol=1e-5, atol=1e-6)
