"""Pipeline parallelism over the pod axis: schedule correctness on 8
devices — forward GPipe vs the sequential stack (incl. the uneven
stage-partition regression), loss AND grads of all three managed
schedules vs the sequential oracle, the full train-step integration, and
the auto-schedule decision trail."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import managed
from repro.parallel import pipeline
from repro.parallel.sharding import smap


def _layer_fn(x, w):
    return jnp.tanh(x @ w)


def test_forward_pipeline_matches_sequential_uneven_stages(mesh8):
    """8-stage GPipe forward over 4 microbatches with n_layers=12 (NOT a
    multiple of 8: stages 0-3 get 2 layers, 4-7 get 1) == the sequential
    stack — the seed's stage_layer_slice dropped the remainder layers."""
    rng = np.random.default_rng(0)
    d, n_layers = 16, 12
    ws = rng.normal(size=(n_layers, d, d)).astype(np.float32) * 0.3
    xs = rng.normal(size=(4, 8, d)).astype(np.float32)   # [M, B, D]

    def stage_fn_factory(n_stage):
        def stage_fn(x, params):
            chunk, per = params
            return pipeline.masked_chunk_apply(_layer_fn, chunk, per, x)
        return stage_fn

    def run(ws_all, mbs):
        sid = jax.lax.axis_index("x")
        chunk, per = pipeline.slice_chunk_params(ws_all, n_layers, 8, sid)
        out = pipeline.pipeline_apply(stage_fn_factory(8), (chunk, per),
                                      mbs, "x")
        return pipeline.select_last_stage(out, "x")

    got = jax.jit(smap(run, mesh8,
                       in_specs=(P(None), P(None)),
                       out_specs=P(None)))(jnp.asarray(ws),
                                           jnp.asarray(xs))
    want = xs
    for layer in range(n_layers):
        want = np.tanh(want @ ws[layer])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=1e-6)


def _train_problem():
    rng = np.random.default_rng(1)
    n_layers, d, m, b = 16, 16, 8, 4
    ws = jnp.asarray(rng.normal(size=(n_layers, d, d)).astype(np.float32)
                     * 0.25)
    xs = jnp.asarray(rng.normal(size=(m, b, d)).astype(np.float32))
    tg = jnp.asarray(rng.normal(size=(m, b, d)).astype(np.float32))
    return n_layers, d, m, b, ws, xs, tg


def test_training_schedules_match_sequential_oracle(mesh8):
    """gpipe == 1f1b == interleaved == sequential autodiff for loss AND
    grads, 8 stages, backward flowing through the pipeline."""
    n_layers, d, m, b, ws, xs, tg = _train_problem()

    def oracle(p):
        losses = []
        for mb in range(m):
            x = xs[mb]
            for i in range(n_layers):
                x = _layer_fn(x, p[i])
            losses.append(jnp.mean((x - tg[mb]) ** 2))
        return jnp.mean(jnp.stack(losses))

    want_loss, want_g = jax.value_and_grad(oracle)(ws)

    for name, v in (("gpipe", 1), ("1f1b", 1), ("interleaved", 2)):
        sched = pipeline.build_schedule(name, m, 8, v)
        n_virtual = 8 * sched.virtual

        def run(p, sched=sched, n_virtual=n_virtual):
            def chunk_fn(pp, q, mb, x):
                x = jnp.where(q == 0, xs[mb], x)
                cp, per = pipeline.slice_chunk_params(pp, n_layers,
                                                      n_virtual, q)
                return pipeline.masked_chunk_apply(_layer_fn, cp, per, x)

            def loss_fn(pp, y, mb):
                return jnp.mean((y - tg[mb]) ** 2)

            return pipeline.pipeline_value_and_grad(
                chunk_fn, loss_fn, p,
                jax.ShapeDtypeStruct((b, d), np.float32), sched, "x")

        loss, grads = jax.jit(smap(run, mesh8, in_specs=(P(None),),
                                   out_specs=(P(None), P(None))))(ws)
        np.testing.assert_allclose(float(loss), float(want_loss),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(grads), np.asarray(want_g),
                                   rtol=3e-4, atol=1e-6)


def test_train_step_pipeline_matches_dp_baseline(mesh222):
    """build_train_step with the pod axis as 1f1b pipeline stages produces
    the same loss and updated params as the hierarchical-DP baseline on
    the same global batch (mean-of-microbatch-means == global token
    mean)."""
    from repro import configs
    from repro.data.pipeline import DataConfig, SyntheticLMData
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.parallel.sharding import MeshCtx
    from repro.train.train_loop import build_train_step

    cfg = configs.get_reduced("granite-34b")
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=32, global_batch=8))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=4)

    def one_step(pipe_mode):
        ctx = MeshCtx.from_mesh(mesh222, mdmp_mode="auto")
        model = Model(cfg, ctx)
        step, pshard, bshard = build_train_step(
            model, opt_cfg, mesh222, pipeline=pipe_mode,
            pipe_microbatches=None if pipe_mode == "none" else 2,
            global_batch=8, seq_len=32)
        params = model.init(jax.random.key(0))
        params = jax.tree.map(jax.device_put, params, pshard)
        opt = adamw_init(params, opt_cfg)
        batch = {k: jax.device_put(v, bshard[k])
                 for k, v in data.global_batch_at(0).items() if k in bshard}
        p2, _, metrics = step(params, opt, batch)
        return float(metrics["loss"]), jax.tree.leaves(p2)

    loss_dp, leaves_dp = one_step("none")
    for sched in ("gpipe", "1f1b"):
        loss_pp, leaves_pp = one_step(sched)
        # bf16 params: grads agree to accumulation-order rounding, so the
        # single AdamW step may flip sign on near-zero coordinates
        assert abs(loss_pp - loss_dp) < 1e-4 * max(1.0, abs(loss_dp))
        for a, b in zip(leaves_pp, leaves_dp):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=3 * float(opt_cfg.lr))


def test_auto_schedule_decision_trail(mesh8):
    """pipeline='auto' resolution on 8 stages lands a pipeline_schedule
    DecisionRecord whose choice builds a valid timetable."""
    managed.clear_decision_log()
    d = managed.resolve_pipeline_schedule("x", 8, 1e-4, 1 << 20,
                                          n_layers=16)
    recs = [r for r in managed.decision_log()
            if r.op == "pipeline_schedule"]
    assert recs and recs[-1].mode == d.schedule
    assert recs[-1].chunks == d.n_micro
    sched = pipeline.build_schedule(d.schedule, d.n_micro, 8, d.virtual)
    assert sched.ticks > 0
