"""Pipeline parallelism over the pod axis: GPipe schedule correctness."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import pipeline
from repro.parallel.sharding import smap


def test_pipeline_matches_sequential():
    """2-stage pipeline over 4 microbatches == sequential layer stack."""
    mesh = jax.make_mesh((2, 4), ("pod", "x"))
    rng = np.random.default_rng(0)
    d = 16
    n_layers = 4                       # 2 per stage
    ws = rng.normal(size=(n_layers, d, d)).astype(np.float32) * 0.3
    xs = rng.normal(size=(4, 8, d)).astype(np.float32)   # [M, B, D]

    def stage_fn(x, params):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, params)
        return out

    def run(ws_all, mbs):
        # this stage's half of the layer stack
        lo, per = pipeline.stage_layer_slice(n_layers, "pod")
        mine = jax.lax.dynamic_slice_in_dim(ws_all, lo, per, axis=0)
        out = pipeline.pipeline_apply(stage_fn, mine, mbs, "pod")
        return pipeline.select_last_stage(out, "pod")

    got = jax.jit(smap(run, mesh,
                       in_specs=(P(None), P(None)),
                       out_specs=P(None)))(jnp.asarray(ws),
                                           jnp.asarray(xs))

    want = xs
    for l in range(n_layers):
        want = np.tanh(want @ ws[l])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=1e-6)
