"""Program-plan conflict, EXECUTED on 8 devices: two declared regions
contend on one mesh axis; the coordinated plan must (a) beat both the
local picks under shared constraints and their concatenation in the
model, and (b) leave the numerics BIT-IDENTICAL to the local-plan
oracle — the backed-off knobs are movement-only (bulk vs ring), so
coordination is free to apply."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import managed
from repro.parallel.sharding import smap
from repro.plan import CommOp, plan_program

N = 8


def _conflict_ops():
    """Two movement-only collectives on one axis with overlapping
    readiness windows.  Region A's compute (1ms) is the pooled overlap
    donor; region B's own hide (0.1ms) makes streaming the LOCAL winner
    for both — but under the shared account B's ring only adds dispatch
    alphas, so the joint pass backs it off to bulk."""
    bw = managed.get_config().hw.link_bw
    nbytes_ag = int(5e-4 * bw / (N - 1))           # wire_A = 0.5 ms
    nbytes_a2a = int(2e-4 * bw * N / (N - 1))      # wire_B = 0.2 ms
    return [
        CommOp(kind="all_gather", label="regionA.acts",
               op_name="all_gather", axis="x", axis_size=N,
               nbytes=nbytes_ag, dtype_bytes=4, phase="fwd",
               window=(0.0, 0.6),
               meta={"collective": "all_gather",
                     "compute_time_s": 1e-3}),
        CommOp(kind="all_to_all", label="regionB.tokens",
               op_name="all_to_all", axis="x", axis_size=N,
               nbytes=nbytes_a2a, dtype_bytes=4, phase="fwd",
               window=(0.1, 0.7),
               meta={"collective": "all_to_all",
                     "compute_time_s": 1e-4}),
    ]


def _step(mesh, ag_mode=None, ag_chunks=None, a2a_mode=None):
    """One program touching BOTH regions' collectives: a gather-matmul
    on region A's operand, a token shuffle on region B's."""

    def f(a, w, t):
        g = managed.managed_all_gather(a, "x", ag_mode, ag_chunks)
        y = jnp.tanh(g @ w)
        z = managed.managed_all_to_all(t, "x", 0, 0, a2a_mode)
        return y, z

    return jax.jit(smap(f, mesh, in_specs=(P("x"), P(None), P("x")),
                        out_specs=(P(None), P("x"))))


def test_conflict_plan_beats_local_and_is_bit_equal(mesh8):
    managed.clear_decision_log()
    plan = plan_program(_conflict_ops())

    # -- the modeled half: coordination is forced and strictly pays ----------
    assert plan.coordinated, plan.summary()
    assert plan.joint_cost_s < plan.local_joint_cost_s
    assert plan.joint_cost_s < plan.local_solo_sum_s
    ag = plan.knob_for("all_gather", "x")
    a2a = plan.knob_for("all_to_all", "x")
    # both stream locally; jointly the a2a backs off to ONE fused dispatch
    choices = {c.op.op_name: c for c in plan.choices}
    assert choices["all_gather"].local_knob["mode"] == "interleaved"
    assert choices["all_to_all"].local_knob["mode"] == "interleaved"
    assert ag["mode"] == "interleaved"
    assert a2a["mode"] == "bulk"
    summary = [r for r in managed.decision_log()
               if r.op == "program_plan"]
    assert len(summary) == 1 and summary[0].mode == "coordinated"

    # -- the executed half: bit-equality across all three resolutions --------
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(N * 4, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(N * 8, 4)).astype(np.float32))

    def run(**kw):
        y, z = _step(mesh8, **kw)(a, w, t)
        return np.asarray(y), np.asarray(z)

    y_local, z_local = run(ag_mode="interleaved", a2a_mode="interleaved")
    with managed.use_plan(plan):
        y_coord, z_coord = run()
    y_amb, z_amb = run()

    # coordinated == local oracle, bit for bit (movement-only knobs)
    np.testing.assert_array_equal(y_coord, y_local)
    np.testing.assert_array_equal(z_coord, z_local)
    # and == the ambient (no plan) resolution too
    np.testing.assert_array_equal(y_coord, y_amb)
    np.testing.assert_array_equal(z_coord, z_amb)
    managed.clear_decision_log()


def test_installed_plan_drives_call_sites(mesh8):
    """The executed trail proves the plan BOUND the call sites: under the
    installed plan the all_gather resolves interleaved (the plan's knob),
    the all_to_all bulk — neither pinned by the caller."""
    plan = plan_program(_conflict_ops(), log=False)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(N * 2, 4)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(N * 8, 4)).astype(np.float32))

    def f(x, y):
        return (managed.managed_all_gather(x, "x"),
                managed.managed_all_to_all(y, "x", 0, 0))

    step = jax.jit(smap(f, mesh8, in_specs=(P("x"), P("x")),
                        out_specs=(P(None), P("x"))))
    managed.clear_decision_log()
    with managed.use_plan(plan):
        step(a, t)
    modes = {r.op: r.mode for r in managed.decision_log()
             if r.op in ("all_gather", "all_to_all")}
    assert modes["all_gather"] == "interleaved"
    assert modes["all_to_all"] == "bulk"
    managed.clear_decision_log()
