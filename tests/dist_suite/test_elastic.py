"""Elastic restart: a checkpoint written on one mesh resumes on ANOTHER
mesh shape bit-exactly (checkpoints store logical unsharded arrays;
re-sharding happens at load — DESIGN.md §4)."""

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import build_train_step


def _run_steps(cfg, mesh_shape, axes, params_np, opt_np, data, n_steps,
               start):
    mesh = jax.make_mesh(mesh_shape, axes)
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    step_fn, pshard, bshard = build_train_step(
        model, AdamWConfig(lr=1e-2), mesh, donate=False)
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params_np,
                          pshard)
    opt = {"mu": jax.tree.map(lambda a, s: jax.device_put(a, s),
                              opt_np["mu"], pshard),
           "nu": jax.tree.map(lambda a, s: jax.device_put(a, s),
                              opt_np["nu"], pshard),
           "step": jax.device_put(opt_np["step"])}
    for i in range(start, start + n_steps):
        batch = {k: jax.device_put(v, bshard[k])
                 for k, v in data.global_batch_at(i).items()}
        params, opt, _ = step_fn(params, opt, batch)
    return (jax.tree.map(np.asarray, params), jax.tree.map(np.asarray, opt))


def test_elastic_resume_across_meshes(tmp_path):
    cfg = dataclasses.replace(configs.get_reduced("granite-34b"),
                              dtype="float32")
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    model0 = Model(cfg, MeshCtx.from_mesh(mesh1))
    params0 = jax.tree.map(np.asarray, model0.init(jax.random.key(0)))
    opt0 = jax.tree.map(np.asarray, adamw_init(params0, AdamWConfig()))
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=32, global_batch=4))

    # reference: 4 steps straight through on (2, 2)
    p_ref, o_ref = _run_steps(cfg, (2, 2), ("data", "model"), params0,
                              opt0, data, 4, 0)

    # elastic: 2 steps on (1, 1) -> checkpoint -> resume on (2, 2)
    p_a, o_a = _run_steps(cfg, (1, 1), ("data", "model"), params0, opt0,
                          data, 2, 0)
    ckpt.save(str(tmp_path), 2, {"params": p_a, "opt": o_a},
              extra={"step": 2})
    restored, extra = ckpt.restore(str(tmp_path), 2,
                                   {"params": p_a, "opt": o_a})
    assert extra["step"] == 2
    p_b, o_b = _run_steps(cfg, (2, 2), ("data", "model"),
                          restored["params"], restored["opt"], data, 2, 2)

    for (k1, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(p_ref)[0],
            jax.tree_util.tree_flatten_with_path(p_b)[0]):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-5,
                                   err_msg=f"elastic {k1}")


def test_elastic_trainloop_resume_replans_tuner_winners(tmp_path):
    """Full TrainLoop elastic resume: a (1,1)-mesh checkpoint carrying
    persisted tuner winners restores onto (2,2); every winner is replayed
    onto the new topology in one replan_for_mesh pass (decision trail
    records old->new), and the continued run matches the straight-through
    (2,2) oracle."""
    from repro.core import managed
    from repro.core.tuner import ScheduleTuner
    from repro.train.train_loop import TrainLoop, TrainLoopConfig

    cfg = dataclasses.replace(configs.get_reduced("granite-34b"),
                              dtype="float32")
    opt_cfg = AdamWConfig(lr=1e-2)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)

    def make_loop(mesh_shape, ckpt_dir, total, tuner):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        model = Model(cfg, MeshCtx.from_mesh(mesh, mdmp_mode="bulk"))
        step_fn, pshard, bshard = build_train_step(model, opt_cfg, mesh,
                                                   donate=False)
        return TrainLoop(step_fn, model, opt_cfg,
                         SyntheticLMData(data_cfg),
                         TrainLoopConfig(total_steps=total, ckpt_every=2,
                                         ckpt_dir=ckpt_dir),
                         pshard, bshard, tuner=tuner)

    # oracle: 4 steps straight through on (2, 2)
    oracle = make_loop((2, 2), str(tmp_path / "oracle"), 4,
                       ScheduleTuner())
    out_ref = oracle.run(*oracle.init_state(seed=0))

    # phase 1 on (1, 1): measured tuner winners accumulate, then persist
    # inside the step-2 checkpoint's extra
    tuner_a = ScheduleTuner()
    halo = tuner_a.decide_halo("data", 1, 1024, 256)
    tuner_a.record(halo.key, "aggregated", 4, 1e-3)
    tuner_a.record(halo.key, "bulk", 1, 2e-3)
    moe = tuner_a.decide_moe("model", 1, 512, 64, 8, 2, 128)
    tuner_a.record(moe.key, "stream", 2, 1e-3)
    tuner_a.record(moe.key, "bulk", 1, 3e-3)
    tuner_a.decide_ckpt("mesh", 1, 1 << 20, 0.05, mtbf_s=120.0)
    loop_a = make_loop((1, 1), str(tmp_path / "elastic"), 2, tuner_a)
    loop_a.run(*loop_a.init_state(seed=0))

    # phase 2 on (2, 2): restore the (1,1) checkpoint, replay winners
    managed.clear_decision_log()
    tuner_b = ScheduleTuner()
    loop_b = make_loop((2, 2), str(tmp_path / "elastic"), 4, tuner_b)
    params, opt, s0 = loop_b.resume_or_init(seed=0)
    assert s0 == 2
    ops = {r["op"]: r for r in loop_b.replayed}
    assert {"halo_jacobi", "moe_dispatch", "ckpt_interval"} <= set(ops)
    assert (ops["halo_jacobi"]["old_n"], ops["halo_jacobi"]["new_n"]) \
        == (1, 2)
    assert "data2" in ops["halo_jacobi"]["new_key"]
    assert "model2" in ops["moe_dispatch"]["new_key"]
    # winners carried onto the new-topology keys, unmeasured
    new_halo = tuner_b.entries[ops["halo_jacobi"]["new_key"]]
    assert (new_halo.mode, new_halo.chunks) == ("aggregated", 4)
    assert new_halo.measured_s == {}
    new_moe = tuner_b.entries[ops["moe_dispatch"]["new_key"]]
    assert (new_moe.mode, new_moe.chunks) == ("stream", 2)
    # the replay is visible in the decision trail
    logged = {rec.op for rec in managed.decision_log()}
    assert {"halo_aggregation", "moe_dispatch", "ckpt_interval"} <= logged

    out_b = loop_b.run(params, opt, s0)
    assert out_b["step"] == 4
    for (k1, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(out_ref["params"])[0],
            jax.tree_util.tree_flatten_with_path(out_b["params"])[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5,
                                   err_msg=f"elastic trainloop {k1}")
