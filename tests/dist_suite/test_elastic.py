"""Elastic restart: a checkpoint written on one mesh resumes on ANOTHER
mesh shape bit-exactly (checkpoints store logical unsharded arrays;
re-sharding happens at load — DESIGN.md §4)."""

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import build_train_step


def _run_steps(cfg, mesh_shape, axes, params_np, opt_np, data, n_steps,
               start):
    mesh = jax.make_mesh(mesh_shape, axes)
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    step_fn, pshard, bshard = build_train_step(
        model, AdamWConfig(lr=1e-2), mesh, donate=False)
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params_np,
                          pshard)
    opt = {"mu": jax.tree.map(lambda a, s: jax.device_put(a, s),
                              opt_np["mu"], pshard),
           "nu": jax.tree.map(lambda a, s: jax.device_put(a, s),
                              opt_np["nu"], pshard),
           "step": jax.device_put(opt_np["step"])}
    for i in range(start, start + n_steps):
        batch = {k: jax.device_put(v, bshard[k])
                 for k, v in data.global_batch_at(i).items()}
        params, opt, _ = step_fn(params, opt, batch)
    return (jax.tree.map(np.asarray, params), jax.tree.map(np.asarray, opt))


def test_elastic_resume_across_meshes(tmp_path):
    cfg = dataclasses.replace(configs.get_reduced("granite-34b"),
                              dtype="float32")
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    model0 = Model(cfg, MeshCtx.from_mesh(mesh1))
    params0 = jax.tree.map(np.asarray, model0.init(jax.random.key(0)))
    opt0 = jax.tree.map(np.asarray, adamw_init(params0, AdamWConfig()))
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=32, global_batch=4))

    # reference: 4 steps straight through on (2, 2)
    p_ref, o_ref = _run_steps(cfg, (2, 2), ("data", "model"), params0,
                              opt0, data, 4, 0)

    # elastic: 2 steps on (1, 1) -> checkpoint -> resume on (2, 2)
    p_a, o_a = _run_steps(cfg, (1, 1), ("data", "model"), params0, opt0,
                          data, 2, 0)
    ckpt.save(str(tmp_path), 2, {"params": p_a, "opt": o_a},
              extra={"step": 2})
    restored, extra = ckpt.restore(str(tmp_path), 2,
                                   {"params": p_a, "opt": o_a})
    assert extra["step"] == 2
    p_b, o_b = _run_steps(cfg, (2, 2), ("data", "model"),
                          restored["params"], restored["opt"], data, 2, 2)

    for (k1, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(p_ref)[0],
            jax.tree_util.tree_flatten_with_path(p_b)[0]):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-5,
                                   err_msg=f"elastic {k1}")
