"""Managed MoE dispatch on 8 devices: every schedule (bulk a2a /
chunked-stream / dense fallback) must produce the single-rank
dense-MoE oracle's loss AND grads for both layouts (ep_a2a and
expert_tp), uniform and skewed routing included; stream == bulk exactly
even when capacity DROPS tokens (same dispatch bookkeeping); auto mode
logs one DecisionRecord per MoE layer; and the full train step
(scan + remat + FSDP + optimizer) agrees across schedules."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import managed
from repro.models import moe
from repro.parallel.sharding import MeshCtx, smap

E_EP, E_TP, K, D, F = 8, 6, 2, 16, 32


def _cfg(impl, n_experts, disp, g=0, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=D, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=64, tp_multiple=1,
        dtype="float32",
        moe=MoEConfig(n_experts=n_experts, top_k=K, d_ff_expert=F,
                      capacity_factor=cf, impl=impl, dispatch=disp,
                      dispatch_g=g))


def _params(n_experts, skew=0.0, seed=0):
    rng = np.random.default_rng(seed)
    p = {
        "w_router": jnp.asarray(rng.normal(size=(D, n_experts))
                                .astype(np.float32)),
        "w1": jnp.asarray(rng.normal(size=(n_experts, D, F))
                          .astype(np.float32) * 0.1),
        "w1_gate": jnp.asarray(rng.normal(size=(n_experts, D, F))
                               .astype(np.float32) * 0.1),
        "w2": jnp.asarray(rng.normal(size=(n_experts, F, D))
                          .astype(np.float32) * 0.1),
    }
    if skew:
        p["w_router"] = p["w_router"].at[:, 0].add(skew)
    return p


@pytest.fixture(scope="module")
def x_global():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.normal(size=(2, 32, D)).astype(np.float32))


def _pspecs(impl):
    if impl == "ep_a2a":           # experts sharded by id over 'model'
        w = P("model", None, None)
        return {"w_router": P(None, None), "w1": w, "w1_gate": w,
                "w2": P("model", None, None)}
    # expert_tp: every expert ff-sharded over 'model'
    return {"w_router": P(None, None), "w1": P(None, None, "model"),
            "w1_gate": P(None, None, "model"),
            "w2": P(None, "model", None)}


def _loss_and_grads(impl, tp, disp, params, x, g=0, cf=8.0, mode="bulk"):
    """Per-rank local loss; the transposed managed collectives carry the
    cross-rank cotangents, so each rank's grads are the TOTAL loss's
    grads for its local parameter shards (the ring-attention dist-test
    pattern).  The psum sits OUTSIDE the autodiff."""
    mesh = jax.make_mesh((1, tp), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=mode)
    cfg = _cfg(impl, params["w_router"].shape[1], disp, g, cf)
    block = (moe.moe_block_ep if impl == "ep_a2a"
             else moe.moe_block_expert_tp)

    def local_loss(pp, xx):
        y, _ = block(xx, pp, cfg, ctx)
        return jnp.sum(y * y)

    def body(pp, xx):
        l, gr = jax.value_and_grad(local_loss)(pp, xx)
        gr["w_router"] = lax.psum(gr["w_router"], "model")
        return lax.psum(l, "model"), gr

    pspecs = _pspecs(impl)
    fn = jax.jit(smap(body, mesh, in_specs=(pspecs, P(None, "model", None)),
                      out_specs=(P(), pspecs)))
    l, gr = fn(params, x)
    return float(l), jax.tree.map(np.asarray, gr)


@pytest.mark.parametrize("impl,n_experts", [("ep_a2a", E_EP),
                                            ("expert_tp", E_TP)])
@pytest.mark.parametrize("skew", [0.0, 3.0])
def test_schedules_match_single_rank_oracle(impl, n_experts, skew,
                                            x_global):
    """8-way bulk == stream == dense == the (1,1) oracle for loss and
    grads, uniform AND skewed routing (capacity ample: nothing drops, so
    the capacity-free dense fallback is exact too)."""
    params = _params(n_experts, skew=skew)
    cf = 16.0 if skew else 8.0
    l_ref, g_ref = _loss_and_grads(impl, 1, "bulk", params, x_global,
                                   cf=cf)
    variants = [("bulk", 0, "bulk"), ("bulk", 0, "interleaved"),
                ("stream", 2, "bulk"), ("stream", 4, "bulk"),
                ("dense", 0, "bulk")]
    for disp, g, mode in variants:
        l, gr = _loss_and_grads(impl, 4, disp, params, x_global, g=g,
                                cf=cf, mode=mode)
        np.testing.assert_allclose(l, l_ref, rtol=3e-5,
                                   err_msg=f"{impl} {disp} skew={skew}")
        for (k, a), (_, b) in zip(sorted(g_ref.items()),
                                  sorted(gr.items())):
            np.testing.assert_allclose(
                a, b, rtol=5e-4, atol=2e-5,
                err_msg=f"{impl} {disp} {k} skew={skew}")


def test_ep_stream_eight_way(x_global):
    """Full 8-rank EP ring (one expert per rank): the streamed dispatch
    still reproduces the oracle through a whole ring cycle of fwd/return
    permutes."""
    params = _params(E_EP)
    l_ref, g_ref = _loss_and_grads("ep_a2a", 1, "bulk", params, x_global)
    l, gr = _loss_and_grads("ep_a2a", 8, "stream", params, x_global, g=2)
    np.testing.assert_allclose(l, l_ref, rtol=3e-5)
    for (k, a), (_, b) in zip(sorted(g_ref.items()), sorted(gr.items())):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=2e-5, err_msg=k)


def test_stream_equals_bulk_under_capacity_drops(x_global):
    """With a starved capacity factor and skewed routing, tokens DROP —
    stream and bulk share the dispatch bookkeeping, so they must agree
    exactly (loss + grads) even though neither matches the drop-free
    oracle."""
    params = _params(E_EP, skew=4.0)
    l_b, g_b = _loss_and_grads("ep_a2a", 4, "bulk", params, x_global,
                               cf=1.0)
    for g in (2, 4):
        l_s, g_s = _loss_and_grads("ep_a2a", 4, "stream", params,
                                   x_global, g=g, cf=1.0)
        np.testing.assert_allclose(l_s, l_b, rtol=1e-6)
        for (k, a), (_, b) in zip(sorted(g_b.items()),
                                  sorted(g_s.items())):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7,
                                       err_msg=f"g={g} {k}")
    # sanity: the starved capacity really did drop assignments
    from repro.core import instrument
    logits = np.asarray(x_global.reshape(-1, D)
                        @ np.asarray(params["w_router"]))
    top_idx = np.argsort(-logits, axis=1)[:, :K]
    from repro.core import cost_model as cm
    rec = instrument.capture_routing(
        "starved", top_idx, E_EP, cm.moe_capacity(16, K, E_EP, 1.0))
    assert rec.drop_rate > 0.0


def test_auto_logs_decision_per_layer(x_global):
    """dispatch='auto' routes through resolve_moe_dispatch and logs one
    moe_dispatch DecisionRecord per (unrolled) layer call."""
    params = _params(E_EP)
    managed.clear_decision_log()
    n_calls = 3
    for _ in range(n_calls):
        _loss_and_grads("ep_a2a", 4, "auto", params, x_global, mode="auto")
    recs = [r for r in managed.decision_log() if r.op == "moe_dispatch"]
    assert len(recs) >= n_calls
    assert all(r.mode in ("bulk", "stream", "dense") for r in recs)
    assert all(r.axis == "model" for r in recs)


# -- full train step: scan + remat + FSDP + optimizer ----------------------


def _train_cfg(disp):
    from repro import configs
    cfg = dataclasses.replace(configs.get_reduced("moonshot-v1-16b-a3b"),
                              dtype="float32")
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=16.0, dispatch=disp))


def _train_once(cfg, mesh_shape, mode, params0, batch_np):
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.train_loop import build_train_step
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode=mode)
    model = Model(cfg, ctx)
    step_fn, pshard, bshard = build_train_step(
        model, AdamWConfig(lr=1e-2), mesh, donate=False)
    params = jax.tree.map(lambda a, s: jax.device_put(np.asarray(a), s),
                          params0, pshard)
    opt = adamw_init(params, AdamWConfig())
    batch = {k: jax.device_put(v, bshard[k]) for k, v in batch_np.items()}
    p2, _, m = step_fn(params, opt, batch)
    return float(m["loss"]), jax.tree.map(np.asarray, p2)


def test_train_step_dispatch_equivalence():
    """moonshot (reduced) on a 2x2 mesh: a streamed-dispatch train step
    == the single-device bulk oracle (loss + post-step params) through
    the full stack — scan over layers, remat, FSDP weight gathers, the
    managed dispatch backward, and the optimizer update."""
    from repro.data.pipeline import DataConfig, SyntheticLMData
    from repro.models.model import Model
    cfg0 = _train_cfg("bulk")
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    model0 = Model(cfg0, MeshCtx.from_mesh(mesh1))
    params0 = jax.tree.map(np.asarray, model0.init(jax.random.key(0)))
    data = SyntheticLMData(DataConfig(vocab_size=cfg0.vocab_size,
                                      seq_len=32, global_batch=4))
    batch = data.global_batch_at(0)
    l_ref, p_ref = _train_once(cfg0, (1, 1), "bulk", params0, batch)
    for disp in ("stream", "dense"):
        l, p = _train_once(_train_cfg(disp), (2, 2), "auto", params0,
                           batch)
        np.testing.assert_allclose(l, l_ref, rtol=1e-3, err_msg=disp)
        for (k1, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(p_ref)[0],
                jax.tree_util.tree_flatten_with_path(p)[0]):
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=3e-4,
                                       err_msg=f"{disp} {k1}")
