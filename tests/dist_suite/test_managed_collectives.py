"""Managed collectives vs bulk oracles on 8 devices: every op, every mode,
chunk counts, gradients through the custom-VJP rings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import managed
from repro.parallel.sharding import smap

N = 8


def run(mesh, fn, in_specs, out_specs, *args):
    return jax.jit(smap(fn, mesh, in_specs=in_specs,
                        out_specs=out_specs))(*args)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return {
        "shard": jnp.asarray(rng.normal(size=(N * 4, 6)).astype(np.float32)),
        "full": jnp.asarray(rng.normal(size=(32, 6)).astype(np.float32)),
        "w": jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32)),
    }


@pytest.mark.parametrize("mode,chunks", [("bulk", 1), ("interleaved", 1),
                                         ("interleaved", 2)])
def test_all_gather(mesh8, data, mode, chunks):
    out = run(mesh8,
              lambda a: managed.managed_all_gather(a, "x", mode, chunks),
              (P("x"),), P(None), data["shard"])
    np.testing.assert_allclose(out, data["shard"], rtol=1e-6)


@pytest.mark.parametrize("mode,chunks", [("bulk", 1), ("interleaved", 1),
                                         ("interleaved", 2)])
def test_reduce_scatter(mesh8, data, mode, chunks):
    out = run(mesh8,
              lambda a: managed.managed_reduce_scatter(a, "x", mode, chunks),
              (P(None),), P("x"), data["full"])
    np.testing.assert_allclose(out, data["full"] * N, rtol=1e-5)


@pytest.mark.parametrize("mode", ["bulk", "interleaved"])
def test_all_reduce(mesh8, data, mode):
    out = run(mesh8,
              lambda a: managed.managed_all_reduce(a, "x", mode=mode),
              (P(None),), P(None, None), data["full"])
    np.testing.assert_allclose(out, data["full"] * N, rtol=1e-5)


@pytest.mark.parametrize("mode", ["bulk", "interleaved"])
@pytest.mark.parametrize("split,concat", [(0, 0), (0, 1), (1, 0)])
def test_all_to_all(mesh8, mode, split, concat):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(N * 8, 16, 3)).astype(np.float32))
    ref = run(mesh8,
              lambda a: lax.all_to_all(a, "x", split, concat, tiled=True),
              (P("x"),), P("x"), x)
    out = run(mesh8,
              lambda a: managed.managed_all_to_all(
                  a, "x", split, concat, mode),
              (P("x"),), P("x"), x)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


@pytest.mark.parametrize("mode,chunks", [("bulk", 1), ("interleaved", 1),
                                         ("interleaved", 2)])
def test_all_gather_matmul(mesh8, data, mode, chunks):
    want = data["shard"] @ data["w"]
    out = run(mesh8,
              lambda a, w: managed.all_gather_matmul(a, w, "x", mode,
                                                     chunks),
              (P("x"), P(None)), P(None), data["shard"], data["w"])
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["bulk", "interleaved"])
def test_all_gather_matmul_multi(mesh8, data, mode):
    rng = np.random.default_rng(2)
    w2 = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    outs = run(mesh8,
               lambda a, wa, wb: tuple(managed.all_gather_matmul_multi(
                   a, [wa, wb], "x", mode)),
               (P("x"), P(None), P(None)), (P(None), P(None)),
               data["shard"], data["w"], w2)
    np.testing.assert_allclose(outs[0], data["shard"] @ data["w"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[1], data["shard"] @ w2,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["bulk", "interleaved"])
def test_matmul_reduce_scatter(mesh8, mode):
    rng = np.random.default_rng(3)
    xf = rng.normal(size=(32, 16)).astype(np.float32)
    wf = rng.normal(size=(16, 5)).astype(np.float32)
    out = run(mesh8,
              lambda a, w: managed.matmul_reduce_scatter(a, w, "x", mode),
              (P(None, "x"), P("x", None)), P("x", None),
              jnp.asarray(xf), jnp.asarray(wf))
    np.testing.assert_allclose(out, xf @ wf, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["bulk", "interleaved"])
def test_ring_grads_match_bulk(mesh8, data, mode):
    """Gradients through the custom-VJP rings equal the bulk-mode grads —
    the duality (AG<->RS, AG-mm<->mm-RS, gram ring) is exact."""
    w = data["w"]

    def loss_fn(mode):
        def f(a, w):
            y = managed.all_gather_matmul(a, w, "x", mode)
            z = managed.matmul_reduce_scatter(
                jnp.tanh(y), w[:5, :6], "x", mode)
            g = managed.managed_all_gather(z, "x", mode)
            return jnp.sum(g ** 2)
        return f

    def grads(mode):
        return run(mesh8, jax.grad(loss_fn(mode), argnums=(0, 1)),
                   (P("x"), P(None)), (P("x"), P(None)),
                   data["shard"], w)

    ga, gwa = grads("bulk")
    gb, gwb = grads("interleaved")
    np.testing.assert_allclose(ga, gb, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gwa, gwb, rtol=1e-4, atol=1e-4)


def test_all_reduce_ring_non_divisible(mesh8):
    """Forced ring mode is honored when axis 0 isn't divisible by the axis
    size: pad-and-slice, not a silent lax.psum demotion.  The decision log
    must show the interleaved schedule actually ran."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32))
    managed.clear_decision_log()
    out = run(mesh8,
              lambda a: managed.managed_all_reduce(a, "x",
                                                   mode="interleaved"),
              (P(None),), P(None, None), x)
    np.testing.assert_allclose(out, x * N, rtol=1e-5)
    recs = [r for r in managed.decision_log() if r.op == "all_reduce"]
    assert recs and all(r.mode == "interleaved" for r in recs)


def test_all_reduce_scalar_fallback_logged(mesh8):
    """0-d operands still fall back to lax.psum — and the DecisionRecord
    says so (mode='bulk'), keeping the audit trail honest."""
    managed.clear_decision_log()
    out = run(mesh8,
              lambda a: managed.managed_all_reduce(a[0, 0], "x",
                                                   mode="interleaved"),
              (P(None),), P(None), jnp.ones((1, 1), jnp.float32))
    np.testing.assert_allclose(out, float(N))
    recs = [r for r in managed.decision_log() if r.op == "all_reduce"]
    assert recs and all(r.mode == "bulk" for r in recs)


def test_bucketed_all_reduce_mixed_dtype(mesh8):
    """Regression: a bf16 leaf ordered FIRST must not drag f32 grads
    through a bf16 round-trip — buckets group by dtype."""
    from repro.core import overlap
    # values chosen to be destroyed by a bf16 cast (1 + 2^-10 etc.)
    f32 = (1.0 + np.arange(24, dtype=np.float32) / 1024.0).reshape(4, 6)
    bf16 = jnp.asarray(np.arange(8, dtype=np.float32), jnp.bfloat16)
    tree = {"a_bf16": bf16, "b_f32": jnp.asarray(f32)}

    out = run(mesh8,
              lambda t: overlap.bucketed_all_reduce(t, "x",
                                                    bucket_bytes=16),
              ({"a_bf16": P(None), "b_f32": P(None)},),
              {"a_bf16": P(None), "b_f32": P(None, None)}, tree)
    assert out["b_f32"].dtype == jnp.float32
    assert out["a_bf16"].dtype == jnp.bfloat16
    # exact: psum of identical f32 values x8 is a power-of-two scale
    np.testing.assert_array_equal(np.asarray(out["b_f32"]), f32 * N)
    np.testing.assert_allclose(
        np.asarray(out["a_bf16"], np.float32),
        np.asarray(bf16, np.float32) * N, rtol=1e-2)


def test_decision_log_records(mesh8, data):
    managed.clear_decision_log()
    run(mesh8, lambda a: managed.managed_all_gather(a, "x", "interleaved"),
        (P("x"),), P(None), data["shard"])
    log = managed.decision_log()
    assert any(r.op == "all_gather" and r.mode == "interleaved"
               for r in log)
