"""Halo exchange + aggregated deep-halo Jacobi vs oracles on 8 devices.

Oracles: numpy ``np.roll`` for the deep periodic exchange, zero slabs at
non-periodic edges (MPI_PROC_NULL), and k unit-step sweeps (the paper's
bulk Figure-2 schedule) for the k-aggregated temporally-blocked solve.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import halo
from repro.parallel.sharding import smap

N = 8
LOCAL = 4          # rows per rank
COLS = 6


@pytest.fixture(scope="module")
def grid():
    rng = np.random.default_rng(42)
    return rng.normal(size=(N * LOCAL, COLS)).astype(np.float32)


def _exchange(mesh8, x, h, periodic):
    """Per-rank (lo, hi) stacked along the sharded axis: global result rows
    [i*2h, i*2h + h) = rank i's lo halo, [i*2h + h, (i+1)*2h) = its hi."""
    fn = jax.jit(smap(
        lambda a: jnp.concatenate(
            halo.halo_exchange(a, "x", halo=h, periodic=periodic), axis=0),
        mesh8, in_specs=(P("x"),), out_specs=P("x")))
    return np.asarray(fn(jnp.asarray(x)))


@pytest.mark.parametrize("h", [1, 2, 3])
def test_halo_exchange_deep_periodic_vs_roll(mesh8, grid, h):
    """periodic deep halo == np.roll: rank i's lo halo is the previous
    rank's last h rows of the rolled-down global array, its hi halo the
    next rank's first h rows of the rolled-up one."""
    got = _exchange(mesh8, grid, h, periodic=True)
    rolled_down = np.roll(grid, h, axis=0)     # row r <- global row r-h
    rolled_up = np.roll(grid, -h, axis=0)      # row r <- global row r+h
    for i in range(N):
        lo = got[i * 2 * h: i * 2 * h + h]
        hi = got[i * 2 * h + h: (i + 1) * 2 * h]
        np.testing.assert_allclose(lo, rolled_down[i * LOCAL:
                                                   i * LOCAL + h])
        np.testing.assert_allclose(
            hi, rolled_up[(i + 1) * LOCAL - h: (i + 1) * LOCAL])


@pytest.mark.parametrize("h", [1, 2, 3])
def test_halo_exchange_deep_nonperiodic_edges_zero(mesh8, grid, h):
    """Non-periodic: interior ranks see true neighbour rows, edge ranks see
    zero slabs (MPI_PROC_NULL semantics)."""
    got = _exchange(mesh8, grid, h, periodic=False)
    for i in range(N):
        lo = got[i * 2 * h: i * 2 * h + h]
        hi = got[i * 2 * h + h: (i + 1) * 2 * h]
        if i == 0:
            np.testing.assert_array_equal(lo, 0.0)
        else:
            np.testing.assert_allclose(lo, grid[i * LOCAL - h: i * LOCAL])
        if i == N - 1:
            np.testing.assert_array_equal(hi, 0.0)
        else:
            np.testing.assert_allclose(
                hi, grid[(i + 1) * LOCAL: (i + 1) * LOCAL + h])


def _solve(mesh8, u, f, iters, mode, **kw):
    fn = jax.jit(smap(
        lambda a, b: halo.jacobi_solve(a, b, "x", iters, mode, **kw),
        mesh8, in_specs=(P("x"), P("x")), out_specs=P("x")))
    return np.asarray(fn(u, f))


@pytest.fixture(scope="module")
def jacobi_data():
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(N * 16, 34)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(N * 16, 34)).astype(np.float32))
    return u, f


@pytest.mark.parametrize("periodic", [False, True])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_aggregated_solve_matches_bulk_oracle(mesh8, jacobi_data, k,
                                              periodic):
    """k-aggregated deep-halo solve (one k-row exchange per k sweeps,
    redundant ghost trapezoid) allclose against k unit-step bulk sweeps."""
    u, f = jacobi_data
    iters = 8
    want = _solve(mesh8, u, f, iters, "bulk", periodic=periodic)
    got = _solve(mesh8, u, f, iters, "aggregated", k=k, periodic=periodic)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("periodic", [False, True])
def test_interleaved_matches_bulk_oracle(mesh8, jacobi_data, periodic):
    """The Figure-3 intermingled schedule must honor the boundary
    condition too (it once silently dropped periodic=True)."""
    u, f = jacobi_data
    want = _solve(mesh8, u, f, 6, "bulk", periodic=periodic)
    got = _solve(mesh8, u, f, 6, "interleaved", periodic=periodic)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_aggregated_solve_remainder_iters(mesh8, jacobi_data):
    """iters not divisible by k: the tail runs unit steps."""
    u, f = jacobi_data
    want = _solve(mesh8, u, f, 7, "bulk")
    got = _solve(mesh8, u, f, 7, "aggregated", k=4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_aggregated_pallas_engine_matches_jnp(mesh8, jacobi_data):
    """The VMEM-resident multi-sweep Pallas kernel and the jnp trapezoid
    share ksweep_trapezoid — same schedule, same numbers."""
    u, f = jacobi_data
    got_jnp = _solve(mesh8, u, f, 8, "aggregated", k=4, engine="jnp")
    got_pl = _solve(mesh8, u, f, 8, "aggregated", k=4, engine="pallas",
                    interpret=True)
    np.testing.assert_allclose(got_pl, got_jnp, rtol=1e-6, atol=1e-6)
    want = _solve(mesh8, u, f, 8, "bulk")
    np.testing.assert_allclose(got_pl, want, rtol=1e-5, atol=1e-5)
