"""Distributed test suite — MUST run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 set before jax imports.
tests/test_distributed.py launches this directory in a subprocess with the
right environment; running it directly inside the main pytest process would
see 1 device and fail loudly here instead of confusingly later.
"""

import os

import jax
import pytest


def pytest_configure(config):
    if jax.device_count() < 8:
        pytest.exit("dist_suite requires 8 devices; run via "
                    "tests/test_distributed.py (subprocess sets XLA_FLAGS)",
                    returncode=3)


@pytest.fixture(scope="session")
def mesh22():
    return jax.make_mesh((2, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh222():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("x",))
