"""End-to-end behaviour tests (single device): training decreases loss,
the fault-tolerant loop restarts from checkpoints, resume is bit-exact,
stragglers are detected."""

import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import (TrainLoop, TrainLoopConfig,
                                    build_train_step)


def _setup(arch="granite-34b", seq=64, batch=4, lr=1e-3):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    cfg = configs.get_reduced(arch)
    model = Model(cfg, ctx)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=5, total_steps=200)
    step_fn, pshard, bshard = build_train_step(model, opt_cfg, mesh)
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=seq, global_batch=batch))
    return model, opt_cfg, step_fn, pshard, bshard, data


def test_loss_decreases():
    model, opt_cfg, step_fn, pshard, bshard, data = _setup()
    params = jax.tree.map(jax.device_put, model.init(jax.random.key(0)),
                          pshard)
    opt = adamw_init(params, opt_cfg)
    losses = []
    for step in range(10):
        batch = {k: jax.device_put(v, bshard[k])
                 for k, v in data.global_batch_at(step).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]


def test_train_loop_fault_recovery(tmp_path):
    model, opt_cfg, step_fn, pshard, bshard, data = _setup()
    loop_cfg = TrainLoopConfig(total_steps=12, ckpt_every=4,
                               ckpt_dir=str(tmp_path / "ckpt"),
                               max_retries=3)
    boom = {"armed": True}

    def fault(step):
        if step == 6 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    loop = TrainLoop(step_fn, model, opt_cfg, data, loop_cfg, pshard,
                     bshard, fault_hook=fault)
    params, opt, s0 = loop.init_state()
    out = loop.run(params, opt, s0)
    assert out["step"] == 12
    assert out["restarts"] == 1
    assert all(np.isfinite(h["loss"]) for h in out["history"])


def test_resume_bit_exact(tmp_path):
    """Interrupted-and-resumed training must equal the uninterrupted run."""
    model, opt_cfg, step_fn, pshard, bshard, data = _setup()

    def run(total, ckpt_dir, resume=False):
        loop = TrainLoop(step_fn, model, opt_cfg, data,
                         TrainLoopConfig(total_steps=total, ckpt_every=4,
                                         ckpt_dir=ckpt_dir),
                         pshard, bshard)
        if resume:
            params, opt, s0 = loop.resume_or_init()
        else:
            params, opt, s0 = loop.init_state()
        return loop.run(params, opt, s0)

    full = run(8, str(tmp_path / "a"))
    _ = run(4, str(tmp_path / "b"))
    resumed = run(8, str(tmp_path / "b"), resume=True)
    for (k1, a), (k2, b) in zip(
            jax.tree_util.tree_flatten_with_path(full["params"])[0],
            jax.tree_util.tree_flatten_with_path(resumed["params"])[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(k1))


def test_straggler_detection():
    model, opt_cfg, step_fn, pshard, bshard, data = _setup()
    import time

    def slow(step):
        if step == 8:
            time.sleep(8.0)   # >> factor x EWMA even under CPU contention

    loop = TrainLoop(step_fn, model, opt_cfg, data,
                     TrainLoopConfig(total_steps=10, ckpt_every=100,
                                     ckpt_dir="/tmp/_nockpt",
                                     straggler_factor=2.0),
                     pshard, bshard, fault_hook=slow)
    params, opt, s0 = loop.init_state()
    out = loop.run(params, opt, s0)
    assert 8 in out["stragglers"]
