"""Overload robustness validation (tier-1, single device).

The PR 7 contracts:
  * token equality — preemption (swap AND drop-recompute) is invisible
    in the output: every evicted request decodes bit-equal to the
    no-overload oracle (greedy chain + exact KV restore / replay);
  * typed degradation — infeasible requests, full queues, and blown
    SLO estimates raise RequestRejected/RequestShed, and pool
    exhaustion raises PagePoolExhausted — never an assert, never a
    livelock;
  * determinism — the overload fault kinds (burst / pool_squeeze) give
    identical shed/preempt/decision sequences across runs;
  * the managed decision — decide_preempt prices swap bytes over PCIe
    vs prefill-replay FLOPs vs head-of-line wait, resolve_preempt logs
    it, the tuner persists it, CommRegion.serve declares it.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import managed
from repro.core.cost_model import PCIE_BW, decide_preempt
from repro.core.faults import FaultPlan
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import (PagedCacheConfig, PagePoolExhausted,
                                  PageTable)
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (Request, RequestRejected, RequestShed,
                                   ServeScheduler)


# ---------------------------------------------------------------------------
# kv_cache: typed exhaustion, squeeze, recovery (satellite: direct tests)
# ---------------------------------------------------------------------------


def _cfg(**kw):
    base = dict(slots=2, page_size=4, n_pages=6, max_pages_per_seq=4)
    base.update(kw)
    return PagedCacheConfig(**base)


def test_page_pool_exhausted_typed_and_recoverable():
    pt = PageTable(_cfg())
    pt.ensure(0, 16)                     # 4 pages
    with pytest.raises(PagePoolExhausted) as ei:
        pt.ensure(1, 12)                 # needs 3, only 2 free
    assert (ei.value.slot, ei.value.need, ei.value.free) == (1, 3, 2)
    # the failing slot got NO partial growth — retry after a release works
    assert pt.pages_held(1) == 0 and pt.free_pages == 2
    pt.release(0)
    pt.ensure(1, 12)
    assert pt.pages_held(1) == 3
    assert pt.high_water == 4            # peak was slot 0's chain


def test_page_table_release_reuse_ordering():
    pt = PageTable(_cfg())
    pt.ensure(0, 8)                      # pages [0, 1]
    first = list(pt.chain(0))
    pt.release(0)
    assert pt.pages_held(0) == 0 and pt.table[0].sum() == 0
    pt.ensure(1, 8)                      # freed pages reused first
    assert sorted(pt.chain(1)) == sorted(first)
    assert pt.free_pages == 4


def test_pool_squeeze_quarantine_and_debt():
    pt = PageTable(_cfg())
    pt.ensure(0, 16)                     # 4 of 6 pages held
    removed = pt.squeeze(0.5)            # target 3 usable, 2 free
    assert removed == 3
    assert pt.free_pages == 0            # both free pages quarantined...
    assert pt.usable_pages == 3          # ...and 1 page owed as debt
    pt.release(0)                        # debt collected from the release
    assert pt.usable_pages == 3 and pt.free_pages == 3
    assert pt.squeeze(0.5) == 0          # already at target


# ---------------------------------------------------------------------------
# metrics: direct units (satellite: empty/partial traces, p99, swap bw)
# ---------------------------------------------------------------------------


def test_metrics_empty_and_partial_traces():
    m = ServeMetrics()
    assert m.ttft_s() == [] and m.tpot_s() == []
    assert m.p99_ttft_s() == 0.0
    assert m.slo_met_tokens(1.0) == 0
    assert m.swap_bw_estimate() is None
    assert m.step_s_estimate() is None
    s = m.summary()
    assert s["p99_ttft_s"] == 0.0 and s["sheds"] == 0
    # a submitted-but-never-served request contributes nothing
    m.on_submit(0, 4, 4)
    assert m.ttft_s() == [] and m.slo_met_tokens(1.0) == 0
    # first token but not done: TTFT counts, TPOT and goodput don't
    m.on_first_token(0)
    m.on_generated(0, 1)
    assert len(m.ttft_s()) == 1 and m.tpot_s() == []
    assert m.slo_met_tokens(100.0) == 0


def test_metrics_p99_swap_bw_and_goodput():
    m = ServeMetrics()
    for rid in range(10):
        m.on_submit(rid, 4, 4)
        t = m.traces[rid]
        t.submit_s, t.first_token_s, t.done_s = 0.0, 0.01 * (rid + 1), 1.0
        t.generated = 4
    assert m.p99_ttft_s() == pytest.approx(0.10)   # the worst of 10
    assert m.slo_met_tokens(0.05) == 5 * 4         # rids 0..4 met
    m.on_shed(99, "queue_full")
    m.on_preempt(3, "swap")
    m.note_swap(1 << 20, 0.5)
    m.note_swap(1 << 20, 0.5)
    assert m.swap_bw_estimate() == pytest.approx(2 << 20)
    s = m.summary()
    assert (s["sheds"], s["preempts"], s["swap_bytes"]) == (1, 1, 2 << 20)


# ---------------------------------------------------------------------------
# scheduler: typed admission control, shedding, drain fix
# ---------------------------------------------------------------------------


def _sched(**kw):
    base = dict(schedule="continuous", chunk=4,
                cache_cfg=_cfg(n_pages=6, max_pages_per_seq=4))
    base.update(kw)
    return ServeScheduler(2, **base)


def _req(rid, p, n, slo=None):
    return Request(rid=rid, prompt=np.arange(1, p + 1, dtype=np.int32),
                   max_new=n, ttft_slo_s=slo)


def test_submit_rejects_infeasible_requests():
    sch = _sched()
    with pytest.raises(RequestRejected, match="max_seq"):
        sch.submit(_req(0, 15, 4))       # 19 tokens > 16-token table
    # 4 pages <= 6-page pool: feasible, accepted
    sch.submit(_req(2, 12, 4))
    assert len(sch.pending) == 1


def test_submit_rejects_over_pool_requests():
    """The livelock fix: a request whose pages exceed the TOTAL pool used
    to pass submit and spin admission forever."""
    sch = _sched(cache_cfg=_cfg(n_pages=3, max_pages_per_seq=4))
    with pytest.raises(RequestRejected, match="never be admitted"):
        sch.submit(_req(0, 12, 4))       # 4 pages > 3-page pool
    sch.submit(_req(1, 8, 4))            # 3 pages: fine
    assert len(sch.pending) == 1


def test_max_queue_backpressure_shed():
    m = ServeMetrics()
    sch = _sched(max_queue=1)
    sch.submit(_req(0, 4, 4), m)
    with pytest.raises(RequestShed, match="max_queue"):
        sch.submit(_req(1, 4, 4), m)
    assert m.sheds == [(1, "queue_full")]
    assert len(sch.pending) == 1         # the queue never overfills


def test_slo_shed_from_queue_wait_estimate():
    m = ServeMetrics()
    sch = _sched(model_step_s=0.1, slo_ttft_s=0.5)
    sch.slots = 1
    sch.submit(_req(0, 4, 4), m)         # est TTFT 0.4s <= 0.5s: queued
    with pytest.raises(RequestShed, match="SLO"):
        sch.submit(_req(1, 4, 4), m)     # backlog 7 steps -> est 1.1s
    assert m.sheds == [(1, "slo")]
    # a per-request SLO overrides the engine default
    sch.submit(_req(2, 4, 4, slo=10.0), m)
    assert len(sch.pending) == 2


def test_watermark_vs_commit_admission():
    pt = PageTable(_cfg(n_pages=6, max_pages_per_seq=4))
    sch = _sched(admission="commit")
    sch.mode = "continuous"
    sch.submit(_req(0, 8, 8))            # commit 4 pages
    sch.submit(_req(1, 8, 8))            # commit would need 8 > 6 total
    assert len(sch.admit(pt)) == 1       # upfront reservation serializes
    sw = _sched(admission="watermark")
    sw.mode = "continuous"
    sw.submit(_req(0, 8, 8))             # prompt = 2 pages only
    sw.submit(_req(1, 8, 8))
    assert len(sw.admit(pt)) == 2        # optimistic: both admitted
    assert sw._committed_pages == 4


def test_drain_retires_finished_requests():
    """Regression (PR 6 latent bug): drain() used to rebuild a FINISHED
    request as a max_new=0 continuation, which re-admission rejects."""
    pt = PageTable(_cfg())
    sch = _sched()
    sch.mode = "continuous"
    sch.submit(_req(0, 4, 2))
    sch.submit(_req(1, 4, 2))
    sch.admit(pt)
    done = sch.active[0]
    done.consumed, done.generated = done.req.total_steps, [7, 8]
    half = sch.active[1]
    half.consumed, half.generated, half.last_out = 4, [9], 9
    results = {}
    out = sch.drain(pt, results)
    assert list(results) == [0]          # finished: retired, not rebuilt
    assert results[0].tolist() == [7, 8]
    assert [r.rid for r, _ in out] == [1]
    cont, prefix = out[0]
    assert cont.max_new >= 1 and prefix == [9]
    assert cont.prompt.tolist() == half.req.prompt.tolist() + [9]
    assert pt.pages_in_use == 0 and not sch.active


def test_victim_selection_deterministic():
    pt = PageTable(_cfg(slots=3, n_pages=12, max_pages_per_seq=8))
    sch = ServeScheduler(3, schedule="continuous", chunk=4,
                         cache_cfg=pt.cfg)
    sch.mode = "continuous"
    for rid, (p, n) in enumerate([(8, 8), (12, 8), (4, 8)]):
        sch.submit(_req(rid, p, n))
    sch.admit(pt)
    for s, rs in sch.active.items():
        pt.ensure(s, len(rs.req.prompt))
        rs.consumed = len(rs.req.prompt)
    assert sch.select_victim(pt) == 1            # most pages held
    assert sch.select_victim(pt, prefer_not=1) == 0
    pt.release(1)
    sch.active[1].consumed = 0
    # tie on pages (slots 0, 2 hold 2 and 1): most pages still wins
    assert sch.select_victim(pt, prefer_not=0) == 2
    # sole-candidate fallback: the growing slot loses its immunity
    pt.release(2)
    assert sch.select_victim(pt, prefer_not=0) == 0


# ---------------------------------------------------------------------------
# faults: the overload kinds (satellite: burst / pool_squeeze units)
# ---------------------------------------------------------------------------


def test_fault_plan_overload_kinds():
    plan = FaultPlan.parse("burst@3:16;pool_squeeze@5:0.5;burst@5:4")
    assert plan.serve_overload(0) == []
    evs = plan.serve_overload(3)
    assert [(e.kind, e.arg) for e in evs] == [("burst", 16.0)]
    assert plan.serve_overload(3) == []          # exactly once
    evs = plan.serve_overload(5)                 # both kinds at one step
    assert sorted((e.kind, e.arg) for e in evs) == \
        [("burst", 4.0), ("pool_squeeze", 0.5)]
    assert plan.unfired() == []
    with pytest.raises(AssertionError):
        FaultPlan.parse("flood@3:1")


# ---------------------------------------------------------------------------
# the cost model / managed / tuner / region decision path
# ---------------------------------------------------------------------------


def test_decide_preempt_prices_three_ways():
    # huge replay vs tiny transfer: swap wins
    d = decide_preempt(2, 1 << 20, 100_000, 1e9, step_s=1e-3)
    assert d.policy == "swap" and d.swap_bytes == 2 << 20
    assert d.predicted_speedup >= 1.0
    # tiny replay vs huge transfer: recompute wins
    d2 = decide_preempt(64, 1 << 28, 4, 1e6, step_s=1e-3)
    assert d2.policy == "recompute"
    # an imminent natural retirement beats both
    d3 = decide_preempt(2, 1 << 20, 100_000, 1e9, step_s=1e-3,
                        wait_s=1e-9)
    assert d3.policy == "wait" and d3.chosen_s == pytest.approx(1e-9)
    # SSM state is not pageable: swap leaves the candidate set
    d4 = decide_preempt(2, 1 << 20, 100_000, 1e9, step_s=1e-3,
                        allow_swap=False)
    assert d4.policy == "recompute"
    # ...even when pinned to the impossible policy
    d5 = decide_preempt(2, 1 << 20, 100_000, 1e9, step_s=1e-3,
                        allow_swap=False, force_policy="swap")
    assert d5.policy == "recompute"
    # measured PCIe bandwidth re-prices the transfer
    slow = decide_preempt(2, 1 << 20, 100_000, 1e9, step_s=1e-3,
                          pcie_bw=PCIE_BW / 1e6)
    assert slow.times["swap"] > d.times["swap"]


def test_resolve_preempt_trail_and_modes():
    managed.clear_decision_log()
    d = managed.resolve_preempt("serve", 2, 1 << 20, 100_000, 1e9,
                                measured_step_s=1e-3)
    rec = managed.decision_log()[-1]
    assert rec.op == "preempt_policy" and rec.mode == d.policy
    assert rec.chunks == 2 and rec.nbytes == d.swap_bytes
    # ambient bulk mode pins the unmanaged drop-everything baseline
    with managed.use_config(managed.MDMPConfig(mode="bulk")):
        db = managed.resolve_preempt("serve", 2, 1 << 20, 100_000, 1e9)
    assert db.policy == "recompute"
    with managed.use_config(managed.MDMPConfig(mode="interleaved")):
        di = managed.resolve_preempt("serve", 2, 1 << 20, 100_000, 1e9)
    assert di.policy == "swap"
    # an explicit policy (tuner winner / --preempt pin) wins over mode
    with managed.use_config(managed.MDMPConfig(mode="bulk")):
        dp = managed.resolve_preempt("serve", 2, 1 << 20, 100_000, 1e9,
                                     policy="swap")
    assert dp.policy == "swap"


def test_tuner_preempt_entry_and_replay(tmp_path):
    from repro.core.tuner import ScheduleTuner, replan_for_mesh
    path = str(tmp_path / "tuner.json")
    t = ScheduleTuner(path=path)
    e = t.decide_preempt("serve", 4, 1 << 20, int(1e9),
                         victim_pages=2, replay_tokens=100_000,
                         step_s=1e-3)
    assert e.key.startswith("preempt")
    assert t.next_trial(e.key) == ScheduleTuner.PREEMPT_CANDIDATES[0]
    t.record(e.key, "swap", 1, 1e-4)
    t.record(e.key, "recompute", 1, 5e-4)
    assert t.entries[e.key].mode == "swap"       # measured winner
    t.save()
    t2 = ScheduleTuner(path=path)
    assert t2.entries[e.key].mode == "swap"
    managed.clear_decision_log()
    replayed = replan_for_mesh(t2, {"serve": 8})
    pre = [r for r in replayed if r["op"] == "preempt"]
    assert pre and pre[0]["mode"] == "swap"      # winner carried forward
    assert any(r.op == "preempt_policy" for r in managed.decision_log())


def test_comm_region_declares_preempt():
    from repro.core.region import CommRegion
    region = CommRegion("serving", axis_sizes={"data": 1})
    region.serve("batching", axis="data", batch_slots=4, mean_prompt=64,
                 mean_new=32, n_params=int(1e8), dtype=jnp.bfloat16,
                 page_bytes=1 << 16, mean_pages=8)
    plan = region.plan(lambda x: x + 1, np.zeros(4, np.float32))
    assert plan.mode_for("batching") in ("static", "continuous")
    assert plan.mode_for("batching.preempt") in ("swap", "recompute",
                                                 "wait")


# ---------------------------------------------------------------------------
# engine: token equality across preemption + deterministic overload
# ---------------------------------------------------------------------------


def _build(arch="granite-34b"):
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    return cfg, mesh, model, params


def _serve(model, mesh, params, prompts, n_new, **kw):
    base = dict(slots=2, max_seq=32, page_size=4, schedule="continuous",
                chunk=4)
    base.update(kw)
    eng = ServeEngine(model, mesh, params, **base)
    rids = [eng.submit(p, n_new) for p in prompts]
    res = eng.run()
    return [res[r] for r in rids], eng


def test_preemption_token_equality_swap_and_recompute():
    """The tentpole invariant: an under-provisioned pool forces
    preemptions, and BOTH eviction paths (page swap to host, drop +
    prefill replay) decode every request bit-equal to the no-overload
    oracle.  The squeeze run drives exhaustion through the pool_squeeze
    fault kind instead of a small pool."""
    cfg, mesh, model, params = _build()     # dense: KV pages swappable
    rng = np.random.default_rng(3)
    plens = [10, 12, 6, 9]
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in plens]
    oracle, eng0 = _serve(model, mesh, params, prompts, 8)
    assert not eng0.metrics.preempts        # ample pool: no evictions

    for policy, kw in (
            ("swap", dict(n_pages=8)),
            ("recompute", dict(n_pages=8)),
            ("swap", dict(fault_plan=FaultPlan.parse("pool_squeeze@1:0.5"),
                          n_pages=12))):
        got, eng = _serve(model, mesh, params, prompts, 8,
                          preempt=policy, **kw)
        assert eng.metrics.preempts, (policy, kw)
        assert all(p == policy for _, p in eng.metrics.preempts)
        for want, g in zip(oracle, got):
            np.testing.assert_array_equal(g, want)
        assert eng.pt.free_pages == eng.pt.usable_pages  # all released


def test_preempt_auto_policy_in_decision_trail():
    cfg, mesh, model, params = _build()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in [10, 12, 6, 9]]
    oracle, _ = _serve(model, mesh, params, prompts, 8)
    managed.clear_decision_log()
    got, eng = _serve(model, mesh, params, prompts, 8, n_pages=8,
                      preempt="auto")
    for want, g in zip(oracle, got):
        np.testing.assert_array_equal(g, want)
    recs = [r for r in managed.decision_log() if r.op == "preempt_policy"]
    # every eviction has a trail record (wait decisions log but don't
    # evict, so filter those when matching the eviction sequence)
    evicted = [r.mode for r in recs if r.mode != "wait"]
    assert len(evicted) >= 1
    assert set(evicted) <= {"swap", "recompute"}
    assert evicted == [p for _, p in eng.metrics.preempts]


def test_preempt_none_reproduces_seed_stall():
    """preempt='none' + an over-pool head request = the seed failure
    mode, caught by the typed stall backstop instead of spinning."""
    cfg, mesh, model, params = _build()
    eng = ServeEngine(model, mesh, params, slots=2, max_seq=32,
                      page_size=4, n_pages=4, schedule="continuous",
                      chunk=4, preempt="none", admission="commit")
    # sneak past the (new) submit check the way the seed code allowed
    rng = np.random.default_rng(5)
    eng.scheduler.pending.append(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size - 1, size=12)
        .astype(np.int32), max_new=8))           # 5 pages > 4-page pool
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()


def test_overload_faults_deterministic():
    """Same plan + same seed => identical shed/preempt/decision/token
    sequences — the determinism contract of the overload fault kinds."""
    cfg, mesh, model, params = _build()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in [10, 8, 6]]

    def run():
        managed.clear_decision_log()
        got, eng = _serve(
            model, mesh, params, prompts, 8, n_pages=8,
            preempt="recompute", max_queue=3,
            fault_plan=FaultPlan.parse("burst@1:6;pool_squeeze@3:0.8"))
        decisions = [(r.op, r.mode, r.chunks)
                     for r in managed.decision_log()
                     if r.op == "preempt_policy"]
        return (got, eng.metrics.sheds, eng.metrics.preempts, decisions,
                sorted((k, v.tolist()) for k, v in eng.results.items()))

    got1, sheds1, pre1, dec1, res1 = run()
    got2, sheds2, pre2, dec2, res2 = run()
    assert sheds1 == sheds2 and sheds1      # backpressure fired...
    assert pre1 == pre2 and pre1            # ...and so did preemption
    assert dec1 == dec2
    assert res1 == res2
    for a, b in zip(got1, got2):
        np.testing.assert_array_equal(a, b)


def test_engine_submit_typed_rejection():
    cfg, mesh, model, params = _build()
    eng = ServeEngine(model, mesh, params, slots=2, max_seq=32,
                      page_size=4, n_pages=4, schedule="continuous",
                      chunk=4)
    rng = np.random.default_rng(7)
    with pytest.raises(RequestRejected):     # typed, not an assert
        eng.submit(rng.integers(0, cfg.vocab_size - 1, size=12)
                   .astype(np.int32), 8)     # 5 pages > 4-page pool
    rid = eng.submit(rng.integers(0, cfg.vocab_size - 1, size=6)
                     .astype(np.int32), 6)
    out = eng.run()
    assert len(out[rid]) == 6
