"""Whole-program communication planner (repro.plan): comm-IR lowering,
joint pricing under shared constraints, the coordinate-descent search,
plan-override precedence in the managed resolvers, and persistence
through the ScheduleTuner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import cost_model, instrument, managed
from repro.core.region import CommRegion
from repro.core.tuner import ScheduleTuner, replan_program_plans
from repro.plan import (CommOp, candidates_for, crosscheck_collectives,
                        lower_collectives, plan_program)
from repro.plan.planner import ProgramPlan, contention_sets, joint_cost


# -- the conflict geometry: two subsystems contending on one axis -----------
#
# Attention's ring streaming owns a huge flash-compute hide (the pooled
# overlap donor); the MoE stream's local pick buys back almost nothing
# under the SHARED account but pays ~steps*(2+g) dispatch alphas.  The
# joint pass should back the MoE off to bulk while keeping the ring.

N_AXIS = 8


def conflict_ops():
    att = CommOp(kind="attention", label="conflict.attention",
                 op_name="attention_schedule", axis="model",
                 axis_size=N_AXIS,
                 nbytes=2 * 4 * 2048 * 2 * 128 * 2, dtype_bytes=2,
                 phase="fwd", window=(0.0, 0.6),
                 meta={"batch": 4, "s_local": 2048, "heads": 32,
                       "kv_heads": 2, "head_dim": 128, "d_model": 4096,
                       "causal": True})
    cap = cost_model.moe_capacity(1024, 2, 16, 1.25)
    moe = CommOp(kind="moe", label="conflict.moe",
                 op_name="moe_dispatch", axis="model", axis_size=N_AXIS,
                 nbytes=16 * cap * 2048 * 2, dtype_bytes=2,
                 phase="fwd", window=(0.1, 0.7),
                 meta={"tokens_local": 1024, "d_model": 2048,
                       "n_experts": 16, "top_k": 2, "d_ff_expert": 512,
                       "capacity_factor": 1.25, "mults": 3})
    return [att, moe]


# -- satellite 1: the DecisionRecord op-name registry ------------------------


def test_registry_rejects_unknown_op():
    with pytest.raises(AssertionError):
        managed.log_decision(managed.DecisionRecord(
            op="not_a_registered_op", axis="x", nbytes=0, mode="bulk",
            chunks=1, predicted_bulk_s=0.0, predicted_interleaved_s=0.0))


def test_every_subsystem_op_is_registered():
    """Exercise every resolver entry point and assert each logged op name
    is in the central registry."""
    managed.clear_decision_log()
    managed.resolve_halo_aggregation("x", 4, 256, 256)
    managed.resolve_attention_schedule("model", 4, 2, 128, 8, 8, 64, 512)
    managed.resolve_pipeline_schedule("pod", 2, 1e-3, 1 << 20)
    managed.resolve_moe_dispatch("model", 4, 256, 128, 8, 2, 256)
    managed.resolve_serve_schedule("serve", 4, 16.0, 16.0, 1e8)
    managed.resolve_preempt("serve", 2, 1 << 16, 16, 1e8)
    managed.resolve_checkpoint("host", 0.1, 1 << 24)
    plan_program(conflict_ops())
    log = managed.decision_log()
    assert {r.op for r in log} >= {
        "halo_aggregation", "attention_schedule", "pipeline_schedule",
        "moe_dispatch", "serve_schedule", "preempt_policy",
        "ckpt_interval", "program_plan"}
    for r in log:
        assert r.op in managed.DECISION_OPS, r.op
    managed.clear_decision_log()


# -- satellite 2: collective extraction records axis + bytes -----------------


def test_instrument_extracts_two_axes():
    """A jaxpr with two collectives on DIFFERENT mesh axes: the walk must
    record each one's axis name and payload bytes."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))

    def body(a, b):
        g = lax.all_gather(a, "x", tiled=True)
        s = lax.psum(b, "y")
        return g.sum() + s.sum()

    f = shard_map(body, mesh=mesh, in_specs=(P("x"), P(None)),
                  out_specs=P(), check_rep=False)
    rep = instrument.analyze_region(f, jnp.ones((4, 2), jnp.float32),
                                    jnp.ones((3,), jnp.float32))
    got = {(c.primitive, c.axis): c.nbytes for c in rep.collectives}
    assert got[("all_gather", "x")] == 4 * 2 * 4
    assert got[("psum", "y")] == 3 * 4
    by_axis = rep.collective_bytes_by_axis()
    assert by_axis["x"] == 32 and by_axis["y"] == 12


# -- IR lowering --------------------------------------------------------------


def test_lower_region_and_windows():
    region = CommRegion("r", axis_sizes={"model": 4})
    region.attention("attn", axis="model", batch=2, s_local=256, heads=8,
                     kv_heads=8, head_dim=64, d_model=512,
                     dtype=jnp.bfloat16)
    region.moe("moe", axis="model", tokens_local=512, d_model=512,
               n_experts=8, top_k=2, d_ff_expert=256, dtype=jnp.bfloat16)
    ops = region.lower()
    assert [o.op_name for o in ops] == ["attention_schedule",
                                        "moe_dispatch"]
    assert all(o.axis == "model" and o.axis_size == 4 and o.nbytes > 0
               for o in ops)
    # default windows overlap -> one contention set
    assert contention_sets(ops) == [[0, 1]]
    for o in ops:
        o2 = CommOp.from_dict(o.to_dict())
        assert o2 == o


def test_lower_collectives_and_crosscheck():
    recs = [instrument.CollectiveRecord("all_gather", "x", 4096, 2),
            instrument.CollectiveRecord("psum", "y", 1024, 5)]
    ops = lower_collectives(recs, {"x": 4, "y": 2}, max_depth=8)
    assert {(o.op_name, o.axis) for o in ops} == {("all_gather", "x"),
                                                  ("all_reduce", "y")}
    # declared ops on axis "x" only; the traced psum on "y" must surface
    # as a discrepancy note
    rep = instrument.RegionReport(records={}, total_eqns=8,
                                  collectives=recs)
    notes = crosscheck_collectives([ops[0]], rep)
    assert any("y" in n for n in notes)


# -- satellite 3 (modeled half): the joint pass beats local concatenation ----


def test_planner_coordinates_conflicting_regions():
    managed.clear_decision_log()
    plan = plan_program(conflict_ops())
    assert plan.coordinated, plan.summary()
    # the coordinated joint cost strictly beats BOTH the local picks under
    # shared constraints and the concatenation of local plans
    assert plan.joint_cost_s < plan.local_joint_cost_s
    assert plan.joint_cost_s < plan.local_solo_sum_s
    moe = next(c for c in plan.choices if c.op.op_name == "moe_dispatch")
    att = next(c for c in plan.choices
               if c.op.op_name == "attention_schedule")
    # locally the MoE streams; jointly it backs off to bulk because the
    # ring attention is the pooled overlap donor
    assert moe.local_knob["mode"] == "stream"
    assert moe.knob["mode"] == "bulk"
    assert att.knob["mode"] == "ring"
    # the trail: one DecisionRecord per op plus the program_plan summary
    log = managed.decision_log()
    summary = [r for r in log if r.op == "program_plan"]
    assert len(summary) == 1 and summary[0].mode == "coordinated"
    assert summary[0].chunks == 2
    assert {r.op for r in log} >= {"attention_schedule", "moe_dispatch"}
    managed.clear_decision_log()


def test_joint_cost_singleton_matches_solo():
    """A one-op program prices identically under joint and solo rules —
    the shared-constraint model degrades gracefully."""
    op = conflict_ops()[1]
    cands = candidates_for(op)
    hw = managed.get_config().hw
    for c in cands:
        assert joint_cost([op], [c], hw=hw) == pytest.approx(
            c.solo_s(hw.alpha_s), rel=1e-12)


def test_disjoint_windows_no_contention():
    """Ops on the same axis with DISJOINT windows (or different axes)
    never share an account: the planner keeps both local picks."""
    a, b = conflict_ops()
    b2 = CommOp.from_dict({**b.to_dict(), "window": [0.7, 1.0]})
    assert contention_sets([a, b2]) == [[0], [1]]
    plan = plan_program([a, b2], log=False)
    assert not plan.coordinated
    assert plan.joint_cost_s == pytest.approx(plan.local_solo_sum_s,
                                              rel=1e-9)


def test_stash_cap_forces_feasible_plan():
    """An infeasible pooled-stash assignment prices to inf, so the
    search lands on a feasible one."""
    op = CommOp(kind="pipeline", label="p", op_name="pipeline_schedule",
                axis="pod", axis_size=4, nbytes=1 << 20, phase="step",
                window=(0.0, 1.0),
                meta={"n_layers": 8, "batch_fwd_s": 1e-3,
                      "batch_bytes": float(1 << 20),
                      "candidate_micro": (4, 8)})
    plan = plan_program([op], stash_cap_bytes=1 << 30, log=False)
    assert plan.joint_cost_s < float("inf")
    chosen = plan.choices[0].knob
    assert chosen["mode"] in ("gpipe", "1f1b", "interleaved")


# -- plan-override precedence in the managed resolvers -----------------------


def test_resolvers_prefer_installed_plan():
    plan = plan_program(conflict_ops(), log=False)
    with managed.use_plan(plan):
        d = managed.resolve_moe_dispatch("model", N_AXIS, 1024, 2048, 16,
                                         2, 512, dtype_bytes=2)
        assert d.schedule == plan.knob_for("moe_dispatch",
                                           "model")["mode"]
        a = managed.resolve_attention_schedule(
            "model", N_AXIS, 4, 2048, 32, 2, 128, 4096, dtype_bytes=2)
        assert a.schedule == "ring"
        # an explicit caller pin still wins over the plan
        d2 = managed.resolve_moe_dispatch("model", N_AXIS, 1024, 2048,
                                          16, 2, 512, dtype_bytes=2,
                                          schedule="stream")
        assert d2.schedule == "stream"
        # the plan has no opinion on other axes -> local resolution
        d3 = managed.resolve_moe_dispatch("other", N_AXIS, 1024, 2048,
                                          16, 2, 512, dtype_bytes=2)
        assert d3.schedule in ("bulk", "stream", "dense")
    assert managed.active_plan() is None


def test_no_plan_behaviour_unchanged():
    """Without an installed plan the resolvers answer exactly as before
    the planner existed (local behaviour is the default)."""
    before = managed.resolve_moe_dispatch("model", N_AXIS, 1024, 2048,
                                          16, 2, 512, dtype_bytes=2)
    assert managed.active_plan() is None
    after = managed.resolve_moe_dispatch("model", N_AXIS, 1024, 2048,
                                         16, 2, 512, dtype_bytes=2)
    assert (before.schedule, before.g) == (after.schedule, after.g)


# -- persistence: the tuner stores and re-plans program plans ----------------


def test_tuner_roundtrip_and_replan(tmp_path):
    plan = plan_program(conflict_ops(), log=False)
    t = ScheduleTuner()
    t.store_program_plan(plan)
    key = ScheduleTuner.program_plan_key(plan.signature, plan.topology)
    assert key in t.program_plans
    path = tmp_path / "tuner.json"
    t.save(str(path))
    t2 = ScheduleTuner()
    t2.load(str(path))
    got = t2.get_program_plan(plan.signature, plan.topology)
    assert isinstance(got, ProgramPlan)
    assert got.knobs == plan.knobs
    assert got.joint_cost_s == pytest.approx(plan.joint_cost_s)

    # topology change: the stored plan re-prices on the new mesh and the
    # replay trail reports it as a program_plan record
    recs = replan_program_plans(t2, {"model": 4})
    assert recs and all(r["op"] == "program_plan" for r in recs)
    new_keys = [k for k in t2.program_plans if "model4" in k]
    assert new_keys, list(t2.program_plans)


def test_program_plan_serialization_roundtrip():
    plan = plan_program(conflict_ops(), log=False)
    d = plan.to_dict()
    back = ProgramPlan.from_dict(d)
    assert back.signature == plan.signature
    assert back.topology == plan.topology
    assert back.knobs == plan.knobs
    assert back.coordinated == plan.coordinated
    assert [c.knob for c in back.choices] == [c.knob for c in plan.choices]
    assert back.knob_for("moe_dispatch", "model") == \
        plan.knob_for("moe_dispatch", "model")
