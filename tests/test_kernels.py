"""Pallas kernel validation: shape/dtype sweeps, interpret mode on CPU,
assert_allclose against the pure-jnp oracles (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (test extra)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import (finalize_partials,
                                           flash_attention_carry_pallas,
                                           flash_attention_pallas,
                                           init_partials, merge_partials)
from repro.kernels.stencil import (jacobi_ksweep_pallas,
                                   jacobi_multistep_pallas,
                                   jacobi_step_pallas)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,h,kvh,hd", [
    (1, 128, 128, 4, 4, 64),      # MHA
    (2, 128, 128, 4, 2, 64),      # GQA 2:1
    (1, 256, 256, 8, 1, 32),      # MQA
    (1, 64, 256, 4, 4, 128),      # cross-shaped (Sq != Skv)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_pallas_vs_ref(b, sq, skv, h, kvh, hd, causal, dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (b, sq, h, hd), dtype)
    k = _rand(rng, (b, skv, kvh, hd), dtype)
    v = _rand(rng, (b, skv, kvh, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, blk_q=64,
                                 blk_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_pallas_sliding_window(window):
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 128, 4, 32), jnp.float32)
    k = _rand(rng, (1, 128, 2, 32), jnp.float32)
    v = _rand(rng, (1, 128, 2, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 blk_q=32, blk_kv=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_blockwise_matches_ref_long():
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 512, 2, 64), jnp.float32)
    k = _rand(rng, (1, 512, 2, 64), jnp.float32)
    v = _rand(rng, (1, 512, 2, 64), jnp.float32)
    out = ops.flash_attention_blockwise(q, k, v, causal=True, blk_kv=128)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=3),
       st.sampled_from([32, 64]))
@settings(max_examples=12, deadline=None)
def test_flash_blockwise_property(b, kvh_mult, hd):
    """Property sweep: blockwise == dense for random GQA configurations."""
    rng = np.random.default_rng(b * 100 + kvh_mult * 10 + hd)
    kvh = kvh_mult
    h = kvh * 2
    q = _rand(rng, (b, 128, h, hd), jnp.float32)
    k = _rand(rng, (b, 128, kvh, hd), jnp.float32)
    v = _rand(rng, (b, 128, kvh, hd), jnp.float32)
    out = ops.flash_attention_blockwise(q, k, v, causal=True, blk_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


# -- online-softmax merge (ring attention's combiner) ------------------------


@given(st.integers(min_value=0, max_value=2 ** 30),
       st.integers(min_value=1, max_value=3),
       st.booleans(),
       st.sampled_from([0, 37]),
       st.integers(min_value=2, max_value=5))
@settings(max_examples=20, deadline=None)
def test_online_softmax_merge_property(seed, kvh, causal, window, n_cuts):
    """Merging flash partials over an ARBITRARY kv-block split (chained
    carry AND pairwise merge_partials, any order) is bit-tolerant against
    attend_ref on the full sequence — causal, sliding-window, and GQA
    head-group cases.  This is the invariant ring attention rests on."""
    rng = np.random.default_rng(seed)
    b, sq, skv, hd = 1, 32, 96, 16
    h = kvh * 2                                     # GQA 2:1
    q = _rand(rng, (b, sq, h, hd), jnp.float32)
    k = _rand(rng, (b, skv, kvh, hd), jnp.float32)
    v = _rand(rng, (b, skv, kvh, hd), jnp.float32)
    q_offset = skv - sq                             # q at the sequence end

    cuts = sorted(set(rng.integers(1, skv, size=n_cuts).tolist()))
    bounds = [0, *cuts, skv]
    segments = list(zip(bounds[:-1], bounds[1:]))

    want = np.asarray(ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset))

    # (a) chained carry through the segments in order
    carry = None
    # (b) independent partials, merged pairwise in REVERSED order
    partials = []
    for lo, hi in segments:
        carry = ops.flash_attention_step(
            q, k[:, lo:hi], v[:, lo:hi], carry, causal=causal,
            window=window, q_offset=q_offset, k_offset=lo)
        partials.append(ops.flash_attention_step(
            q, k[:, lo:hi], v[:, lo:hi], None, causal=causal,
            window=window, q_offset=q_offset, k_offset=lo))
    out_chain, _ = finalize_partials(*carry)
    merged = partials[-1]
    for p in reversed(partials[:-1]):
        merged = merge_partials(merged, p)
    out_merge, _ = finalize_partials(*merged)

    np.testing.assert_allclose(np.asarray(out_chain), want,
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(out_merge), want,
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_carry_pallas_matches_jnp_engine(causal):
    """The Pallas carry kernel (interpret mode) and the jnp engine produce
    the same partials for the same KV block, traced offsets included."""
    rng = np.random.default_rng(5)
    b, sq, skv, h, kvh, hd = 1, 64, 64, 4, 2, 32
    q = _rand(rng, (b, sq, h, hd), jnp.float32)
    k = _rand(rng, (b, skv, kvh, hd), jnp.float32)
    v = _rand(rng, (b, skv, kvh, hd), jnp.float32)
    m0, l0, a0 = init_partials(b, sq, h, hd)
    got = flash_attention_carry_pallas(
        q, k, v, m0, l0, a0, causal=causal, q_offset=jnp.int32(64),
        k_offset=jnp.int32(32), blk_q=32, blk_kv=32, interpret=True)
    want = ops._flash_step_jnp(q, k, v, m0, l0, a0, causal, 0,
                               jnp.int32(64), jnp.int32(32), 32)
    for g, w, nm in zip(got, want, ("m", "l", "acc")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5, err_msg=nm)


@pytest.mark.parametrize("m,n,bm,bn", [
    (66, 130, 64, 128),
    (130, 130, 64, 64),
    (258, 514, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_jacobi_pallas_vs_ref(m, n, bm, bn, dtype):
    rng = np.random.default_rng(3)
    u = _rand(rng, (m, n), dtype)
    f = _rand(rng, (m, n), dtype)
    out = jacobi_step_pallas(u, f, blk_m=bm, blk_n=bn, interpret=True)
    want = ref.jacobi_step_ref(u, f)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,n,bm", [
    (66, 130, 64),        # single-tile fallback (66 % 64 != 0)
    (256, 130, 64),       # 4-block grid
    (128, 258, 16),       # 8-block grid, tiny tiles
])
@pytest.mark.parametrize("k", [1, 2, 3, 4, 8])
def test_jacobi_multistep_vs_k_unit_sweeps(m, n, bm, k):
    """The temporally-blocked kernel (k sweeps per HBM round-trip) must
    match k applications of the unit-sweep oracle exactly — the trapezoid
    plus frozen Dirichlet edges is redundant compute, not approximation."""
    rng = np.random.default_rng(7)
    u = _rand(rng, (m, n), jnp.float32)
    f = _rand(rng, (m, n), jnp.float32)
    out = jacobi_multistep_pallas(u, f, k=k, blk_m=bm, interpret=True)
    want = ref.jacobi_multistep_ref(u, f, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_jacobi_multistep_bf16():
    rng = np.random.default_rng(8)
    u = _rand(rng, (128, 130), jnp.bfloat16)
    f = _rand(rng, (128, 130), jnp.bfloat16)
    out = jacobi_multistep_pallas(u, f, k=4, blk_m=32, interpret=True)
    want = ref.jacobi_multistep_ref(u, f, 4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("k", [1, 2, 4])
def test_jacobi_ksweep_slab_interior(k):
    """The distributed slab kernel: with a k-deep apron of true neighbour
    rows (frozen depths 0), the center must equal k unit sweeps of the
    larger grid restricted to the center rows."""
    rng = np.random.default_rng(9)
    m, n = 64, 130
    big = _rand(rng, (m + 2 * k, n), jnp.float32)
    fbig = _rand(rng, (m + 2 * k, n), jnp.float32)
    out = jacobi_ksweep_pallas(big, fbig, k, 0, 0, blk_m=32, interpret=True)
    # Oracle: k sweeps on the padded grid where EVERY row updates (the slab
    # kernel's apron rows are live neighbour rows, not Dirichlet): emulate
    # by padding the big grid with one more frozen ring per sweep.
    want = big
    for _ in range(k):
        z = jnp.zeros((1, n), jnp.float32)
        up = jnp.concatenate([z, want, z], axis=0)
        new = 0.25 * (up[:-2, 1:-1] + up[2:, 1:-1] + up[1:-1, :-2]
                      + up[1:-1, 2:] - fbig[:, 1:-1])
        want = want.at[:, 1:-1].set(new)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(want[k:-k]),
                               rtol=1e-6, atol=1e-6)


def test_jacobi_converges():
    """Sweeps reduce the residual of Laplace's equation (sanity that the
    kernel computes the right operator, not just matches the ref once)."""
    n = 66
    u = jnp.zeros((n, n), jnp.float32).at[0, :].set(1.0)
    f = jnp.zeros((n, n), jnp.float32)
    def residual(u):
        r = ref.jacobi_step_ref(u, f) - u
        return float(jnp.abs(r).max())
    r0 = residual(u)
    for _ in range(50):
        u = jacobi_step_pallas(u, f, blk_m=64, blk_n=64, interpret=True)
    assert residual(u) < r0
