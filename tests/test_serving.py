"""Serving runtime validation (tier-1, single device).

Three layers of oracles:
  * kernel — paged-attention Pallas (interpret) == jnp page-scan engine
    == dense reference on randomized page tables;
  * cache — paged greedy decode == the contiguous-cache Generator
    (same tokens, per family);
  * scheduler — continuous batching == one-request-at-a-time decoding,
    and it finishes mixed-length queues in strictly fewer quanta than
    static waves (the deterministic form of the throughput win).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.core import managed
from repro.kernels import paged_attention as paged
from repro.kernels import ref
from repro.models.model import Model
from repro.parallel.sharding import MeshCtx, infer_shardings
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import PagedCacheConfig, PageTable
from repro.train.serve_loop import Generator


# ---------------------------------------------------------------------------
# Kernel: pallas == jnp == dense on randomized page tables
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh,hd,page,pmax", [
    (8, 2, 32, 8, 5),     # GQA 4:1
    (4, 4, 16, 4, 7),     # MHA, small pages
    (8, 1, 64, 16, 3),    # MQA
])
@pytest.mark.parametrize("window", [0, 9])
def test_paged_attention_pallas_vs_jnp(h, kvh, hd, page, pmax, window):
    rng = np.random.default_rng(h * 100 + page + window)
    b, npool = 3, 32
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(npool, page, kvh, hd))
                     .astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(npool, page, kvh, hd))
                     .astype(np.float32))
    table = jnp.asarray(rng.permutation(npool)[:b * pmax]
                        .reshape(b, pmax).astype(np.int32))
    lens = jnp.asarray(rng.integers(0, page * pmax + 1, size=b)
                       .astype(np.int32))
    o_jnp = paged.paged_attention_jnp(q, kp, vp, table, lens,
                                      window=window)
    o_pal = paged.paged_attention_pallas(q, kp, vp, table, lens,
                                         window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_jnp),
                               rtol=2e-5, atol=2e-5)
    # dense oracle per slot: gather the page chain contiguously
    for i in range(b):
        n = int(lens[i])
        if n == 0:
            np.testing.assert_array_equal(np.asarray(o_jnp[i]), 0.0)
            continue
        kc = np.concatenate([np.asarray(kp[int(table[i, j])])
                             for j in range(pmax)])[:n]
        vc = np.concatenate([np.asarray(vp[int(table[i, j])])
                             for j in range(pmax)])[:n]
        lo = max(0, n - window) if window else 0
        want = ref.flash_attention_ref(
            q[i:i + 1, None], jnp.asarray(kc[lo:])[None],
            jnp.asarray(vc[lo:])[None], causal=False)
        np.testing.assert_allclose(np.asarray(want)[0, 0],
                                   np.asarray(o_jnp[i]), rtol=2e-5,
                                   atol=2e-5)


def test_paged_partials_shard_merge():
    """Partials over disjoint pool shards LSE-merge to the full result —
    the distributed flash-decoding contract of attention_decode_paged."""
    from repro.kernels.flash_attention import (finalize_partials,
                                               merge_partials)
    rng = np.random.default_rng(3)
    b, h, kvh, hd, page, pmax, npool = 2, 4, 2, 16, 4, 6, 16
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(npool, page, kvh, hd))
                     .astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(npool, page, kvh, hd))
                     .astype(np.float32))
    table = jnp.asarray(rng.permutation(npool)[:b * pmax]
                        .reshape(b, pmax).astype(np.int32))
    lens = jnp.asarray(np.array([17, 23], np.int32))
    full = paged.paged_attention_jnp(q, kp, vp, table, lens)
    parts = [paged.paged_attention_partials_jnp(
        q, kp[o:o + 4], vp[o:o + 4], table, lens, pool_offset=o)
        for o in (0, 4, 8, 12)]
    acc = parts[0]
    for p in parts[1:]:
        acc = merge_partials(acc, p)
    out, _ = finalize_partials(*acc, out_dtype=q.dtype)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Engine: paged == contiguous oracle; continuous == sequential oracle
# ---------------------------------------------------------------------------


def _build(arch):
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    return cfg, mesh, model, params


@pytest.mark.parametrize("arch", ["granite-34b", "mamba2-130m",
                                  "hymba-1-5b", "moonshot-v1-16b-a3b"])
def test_paged_generator_matches_contiguous(arch):
    """Generator(engine='paged') greedy-decodes the SAME tokens as the
    contiguous-cache oracle (dense / ssm / hybrid-with-SWA / moe
    families — moe_block_decode behind the serving engine had no
    coverage before PR 5)."""
    cfg, mesh, model, params = _build(arch)
    shape = ShapeConfig("serve", seq_len=32, global_batch=2, kind="decode")
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size - 1, size=(2, 5)) \
        .astype(np.int32)
    want = Generator(model, mesh, shape, params).generate(prompts, n_new=6)
    got = Generator(model, mesh, shape, params, engine="paged",
                    page_size=4).generate(prompts, n_new=6)
    np.testing.assert_array_equal(got, want)


def test_continuous_batching_matches_sequential_oracle():
    """Mixed-length queue through 2 continuously-batched slots decodes
    every request to the same tokens as one-request-at-a-time, in
    strictly fewer quanta than static waves, reusing freed pages."""
    cfg, mesh, model, params = _build("granite-34b")
    shape = ShapeConfig("serve", seq_len=32, global_batch=1, kind="decode")
    gen = Generator(model, mesh, shape, params)
    rng = np.random.default_rng(1)
    plens = [4, 9, 3, 7, 5, 2]
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in plens]
    oracle = [gen.generate(p[None], n_new=6)[0] for p in prompts]

    def run(schedule):
        eng = ServeEngine(model, mesh, params, slots=2, max_seq=32,
                          page_size=4, schedule=schedule, chunk=4)
        rids = [eng.submit(p, 6) for p in prompts]
        res = eng.run()
        return [res[r] for r in rids], eng

    got_c, eng_c = run("continuous")
    got_s, eng_s = run("static")
    for want, gc, gs in zip(oracle, got_c, got_s):
        np.testing.assert_array_equal(gc, want)
        np.testing.assert_array_equal(gs, want)
    # the deterministic throughput win: fewer dispatched quanta for the
    # same work (static waves pad to the wave's longest request)
    assert len(eng_c.metrics.quanta) < len(eng_s.metrics.quanta), (
        eng_c.metrics.summary(), eng_s.metrics.summary())
    assert eng_c.metrics.occupancy() > eng_s.metrics.occupancy()
    # paging: the pool never had to hold all 6 requests at once
    assert eng_c.pt.high_water <= 2 * eng_c.cache_cfg.max_pages_per_seq
    assert eng_c.pt.free_pages == eng_c.cache_cfg.n_pages  # all released


def test_paged_engine_uses_pallas_kernel(monkeypatch):
    """REPRO_PALLAS=interpret routes decode attention through the Pallas
    paged kernel inside the full engine (single-shard pool fast path)."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    cfg, mesh, model, params = _build("granite-34b")
    shape = ShapeConfig("serve", seq_len=16, global_batch=1, kind="decode")
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size - 1, size=(1, 4)) \
        .astype(np.int32)
    got = Generator(model, mesh, shape, params, engine="paged",
                    page_size=4).generate(prompts, n_new=3)
    monkeypatch.delenv("REPRO_PALLAS")
    want = Generator(model, mesh, shape, params, engine="paged",
                     page_size=4).generate(prompts, n_new=3)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# The managed decision + bookkeeping units
# ---------------------------------------------------------------------------


def test_decide_serve_schedule_model():
    from repro.core import cost_model as cm
    # mixed lengths: continuous wins; uniform: static never loses
    d = cm.decide_serve_schedule(1e8, 8, 64, 32, max_prompt=256)
    assert d.mode == "continuous" and d.predicted_speedup > 1.0
    assert f"{d.mode}:{d.chunk}" in d.tok_s
    du = cm.decide_serve_schedule(1e8, 8, 64, 32, max_prompt=64)
    assert du.static_tok_s >= max(
        v for k, v in du.tok_s.items() if k.startswith("continuous"))
    # pinning
    df = cm.decide_serve_schedule(1e8, 8, 64, 32, max_prompt=256,
                                  force_mode="static", force_chunk=5)
    assert (df.mode, df.chunk) == ("static", 5)
    # TTFT budget drops big quanta
    db = cm.decide_serve_schedule(1e8, 8, 64, 32, max_prompt=256,
                                  measured_step_s=1e-3,
                                  ttft_budget_s=0.08)
    assert db.ttft_s <= 0.08 or db.chunk == 1


def test_resolve_serve_schedule_trail_and_tuner(tmp_path):
    from repro.core.tuner import ScheduleTuner
    managed.clear_decision_log()
    d = managed.resolve_serve_schedule("serve", 8, 64, 32, 1e8,
                                       max_prompt=256)
    rec = managed.decision_log()[-1]
    assert rec.op == "serve_schedule"
    assert rec.mode == d.mode and rec.chunks == d.chunk
    # bulk mode pins the unmanaged baseline (static waves)
    with managed.use_config(managed.MDMPConfig(mode="bulk")):
        db = managed.resolve_serve_schedule("serve", 8, 64, 32, 1e8,
                                            max_prompt=256)
    assert db.mode == "static"
    # tuner: model seed, measured override, persistence, sweep
    path = str(tmp_path / "tuner.json")
    t = ScheduleTuner(path=path)
    e = t.decide_serve(8, 64, 32, int(1e8), max_prompt=256)
    assert t.next_trial(e.key) == ScheduleTuner.SERVE_CANDIDATES[0]
    t.record(e.key, "continuous", 8, 1e-4)
    t.record(e.key, "static", 8, 5e-4)
    assert (t.entries[e.key].mode, t.entries[e.key].chunks) == \
        ("continuous", 8)
    t.save()
    t2 = ScheduleTuner(path=path)
    assert t2.entries[e.key].mode == "continuous"


def test_comm_region_serve_declaration():
    from repro.core.region import CommRegion
    region = CommRegion("serving", axis_sizes={"data": 2})
    region.serve("batching", axis="data", batch_slots=8, mean_prompt=64,
                 mean_new=32, max_prompt=256, n_params=int(1e8),
                 dtype=jnp.bfloat16)
    plan = region.plan(lambda x: x + 1, np.zeros(4, np.float32))
    assert plan.mode_for("batching") in ("static", "continuous")
    assert plan.chunks_for("batching") >= 1


def test_page_table_free_list():
    cfg = PagedCacheConfig(slots=2, page_size=4, n_pages=6,
                           max_pages_per_seq=3)
    pt = PageTable(cfg)
    pt.ensure(0, 9)                     # 3 pages
    pt.ensure(1, 1)                     # 1 page
    assert pt.pages_held(0) == 3 and pt.pages_held(1) == 1
    assert pt.free_pages == 2
    assert sorted(pt.table[0].tolist()) == [0, 1, 2]
    pt.release(0)
    assert pt.free_pages == 5
    pt.ensure(1, 12)                    # grows to 3, reuses freed pages
    assert pt.pages_held(1) == 3 and pt.free_pages == 3
    assert pt.high_water == 4
    assert not pt.can_fit(16) and pt.can_fit(12)
