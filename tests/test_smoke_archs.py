"""Per-arch smoke tests (assignment deliverable f): a REDUCED config of
each family runs one forward/train step on CPU; output shapes + no NaNs.
The FULL configs are exercised only via the dry-run."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import build_train_step


@pytest.mark.parametrize("arch", configs.list_archs())
def test_reduced_train_step(arch):
    cfg = configs.get_reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    step_fn, pshard, bshard = build_train_step(model, AdamWConfig(), mesh)
    params = jax.tree.map(jax.device_put, model.init(jax.random.key(0)),
                          pshard)
    opt = adamw_init(params, AdamWConfig())
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=64, global_batch=4))
    b = dict(data.global_batch_at(0))
    rng = np.random.default_rng(0)
    if cfg.encoder is not None:
        b["frames"] = rng.normal(size=(4, cfg.encoder.n_frames,
                                       cfg.d_model)).astype(np.float32)
    if cfg.vision is not None:
        b["patches"] = rng.normal(size=(4, cfg.vision.n_patches,
                                        cfg.d_model)).astype(np.float32)
    batch = {k: jax.device_put(v, bshard[k]) if k in bshard else v
             for k, v in b.items()}
    params2, opt2, m = step_fn(params, opt, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    # shapes preserved, values updated, nothing went NaN
    for (k1, a), (k2, c) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(params2)[0]):
        assert a.shape == c.shape, k1
        assert np.isfinite(np.asarray(c, dtype=np.float32)).all(), k1


@pytest.mark.parametrize("arch", configs.list_archs())
def test_reduced_decode_step(arch):
    from repro.configs.base import ShapeConfig
    from repro.train.serve_loop import Generator
    from repro.parallel.sharding import infer_shardings

    cfg = configs.get_reduced(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    shape = ShapeConfig("smoke", seq_len=16, global_batch=2, kind="decode")
    gen = Generator(model, mesh, shape, params)
    prompt = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    out = gen.generate(prompt, n_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
