"""Launcher for the distributed suite: spawns pytest on tests/dist_suite
in a subprocess with 8 forced host devices (the env var must be set before
jax initialises, which is impossible in-process once any test imported
jax)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(3000)
def test_distributed_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "pytest",
         os.path.join(os.path.dirname(__file__), "dist_suite"),
         "-x", "-q", "--no-header", "-p", "no:cacheprovider"],
        env=env, capture_output=True, text=True, timeout=2900)
    sys.stdout.write(proc.stdout[-8000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed suite failed"
