"""Checkpoint subsystem: atomicity across crashes, directory hygiene,
async-writer error surfacing, ml_dtypes round-trips, corrupt-shard
fallback, and the metered async drain."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore,
                              restore_latest, save, valid_steps)
from repro.core.faults import corrupt_latest


def _tree(seed=0, n=64):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(n, 8)).astype(np.float32),
            "b": rng.normal(size=(8,)).astype(np.float32)}


def test_crash_mid_save_never_corrupts_latest(tmp_path):
    """A crash between staging and commit leaves only a ``.tmp`` dir (or a
    partial step dir); latest_step must keep trusting the previous
    committed checkpoint."""
    d = str(tmp_path)
    save(d, 2, _tree(), extra={"step": 2})
    # crash A: staging dir never replaced
    tmp = os.path.join(d, "step_00000004.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(b"partial")
    # crash B: a step dir missing its manifest (pre-atomic-commit layout)
    part = os.path.join(d, "step_00000006")
    os.makedirs(part)
    with open(os.path.join(part, "arrays.npz"), "wb") as f:
        f.write(b"partial")
    assert latest_step(d) == 2
    tree, extra, step = restore_latest(d, _tree())
    assert step == 2 and extra["step"] == 2
    np.testing.assert_array_equal(tree["w"], _tree()["w"])


def test_gc_removes_stale_tmp_dirs(tmp_path):
    d = str(tmp_path)
    stale = os.path.join(d, "step_00000001.tmp")
    os.makedirs(stale)
    mgr = CheckpointManager(d, keep=1)
    mgr.save_async(2, _tree(), extra={"step": 2})
    mgr.wait()
    mgr.save_async(4, _tree(), extra={"step": 4})
    mgr.wait()
    assert not os.path.exists(stale), "stale .tmp dir survived GC"
    assert valid_steps(d) == [4], "keep=1 retention failed"


def test_wait_surfaces_writer_error(tmp_path):
    """The async writer's exception must surface on wait(), not vanish
    with the thread."""
    blocker = tmp_path / "ckpt"
    blocker.write_text("not a directory")
    mgr = CheckpointManager(str(blocker))
    mgr.save_async(1, _tree())
    with pytest.raises(Exception):
        mgr.wait()
    # the error queue drains: a second wait is clean
    mgr.wait()


@pytest.mark.parametrize("dtype", ["bfloat16", "float8_e4m3fn"])
def test_narrow_dtype_roundtrip(tmp_path, dtype):
    """npz can't hold ml_dtypes: the manifest records the true dtype and
    restore narrows back — bit-exact, since f32 superset both."""
    d = str(tmp_path)
    x = jnp.asarray(np.linspace(-2, 2, 32, dtype=np.float32)).astype(dtype)
    tree = {"x": x, "y": np.arange(4, dtype=np.int32)}
    save(d, 1, tree)
    with open(os.path.join(d, "step_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    assert {k["key"]: k["dtype"] for k in manifest["keys"]}["x"] == dtype
    out, _ = restore(d, 1, tree)
    assert str(out["x"].dtype) == dtype
    np.testing.assert_array_equal(np.asarray(out["x"], np.float32),
                                  np.asarray(x, np.float32))
    np.testing.assert_array_equal(out["y"], tree["y"])


def test_restore_latest_falls_back_past_corrupt_shard(tmp_path):
    """A truncated arrays.npz passes the directory check but fails the
    load: restore_latest must quarantine it and fall back to the previous
    step (the corrupt@k fault's recovery path)."""
    d = str(tmp_path)
    save(d, 2, _tree(2), extra={"step": 2})
    save(d, 4, _tree(4), extra={"step": 4})
    assert corrupt_latest(d, keep_bytes=16) is not None
    assert latest_step(d) == 4          # damage is invisible until load
    tree, extra, step = restore_latest(d, _tree())
    assert step == 2 and extra["step"] == 2
    np.testing.assert_array_equal(tree["w"], _tree(2)["w"])
    assert os.path.exists(os.path.join(d, "step_00000004.corrupt"))
    assert latest_step(d) == 2          # quarantined, never retried


def test_async_chunked_drain_roundtrip_and_metrics(tmp_path):
    """A tiny drain chunk forces the multi-piece D2H path; the write must
    still restore exactly, and the save's counters must land in
    CheckpointMetrics (the cadence decision's inputs)."""
    d = str(tmp_path)
    rng = np.random.default_rng(7)
    tree = {"big": jax.device_put(rng.normal(size=(256, 32))
                                  .astype(np.float32)),
            "small": jax.device_put(np.float32(3.5))}
    mgr = CheckpointManager(d, drain_chunk_bytes=1024)   # 8 rows per chunk
    mgr.save_async(3, tree, extra={"step": 3})
    mgr.wait()
    out, extra, step = restore_latest(d, jax.tree.map(np.asarray, tree))
    assert step == 3
    np.testing.assert_array_equal(out["big"], np.asarray(tree["big"]))
    np.testing.assert_array_equal(out["small"], np.asarray(tree["small"]))
    m = mgr.metrics
    assert len(m.saves) == 1
    rec = m.saves[0]
    assert rec.nbytes == 256 * 32 * 4 + 4
    assert rec.snapshot_s > 0 and rec.drain_s > 0 and rec.write_s > 0
    assert m.write_bw_estimate() > 0
    assert m.ckpt_cost_s_estimate() > 0
