"""Property tests (hypothesis) for the MDMP cost model — the decision
engine's invariants must hold for ANY workload."""

import math

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (test extra)")
from hypothesis import given, settings, strategies as st

from repro.core import cost_model as cm


sizes = st.integers(min_value=1, max_value=64)
nbytes = st.floats(min_value=1.0, max_value=1e12, allow_nan=False)
compute = st.floats(min_value=0.0, max_value=10.0, allow_nan=False)


@given(nbytes=nbytes, n=sizes)
@settings(max_examples=200, deadline=None)
def test_no_compute_never_interleaves(nbytes, n):
    """With zero fusable compute, chunking buys nothing — the manager must
    keep the bulk schedule (no free lunch from latency alone)."""
    d = cm.decide(nbytes, n, compute_time_s=0.0)
    assert d.mode == "bulk"


@given(nbytes=nbytes, n=st.integers(min_value=2, max_value=64),
       compute=st.floats(min_value=1e-6, max_value=10.0))
@settings(max_examples=200, deadline=None)
def test_interleaved_never_predicted_worse_than_chosen(nbytes, n, compute):
    """decide() must never pick a schedule it predicts to be slower than
    bulk."""
    d = cm.decide(nbytes, n, compute_time_s=compute)
    assert d.interleaved_time_s <= d.bulk_time_s * (1 + 1e-9)


@given(nbytes=nbytes, n=sizes, compute=compute)
@settings(max_examples=200, deadline=None)
def test_times_positive_and_monotone_in_bytes(nbytes, n, compute):
    d1 = cm.decide(nbytes, n, compute_time_s=compute)
    d2 = cm.decide(nbytes * 2, n, compute_time_s=compute)
    assert d1.comm_time_s >= 0
    assert d2.comm_time_s >= d1.comm_time_s


@given(n=st.integers(min_value=2, max_value=64),
       nbytes=st.floats(min_value=1e3, max_value=1e9))
@settings(max_examples=100, deadline=None)
def test_ring_identities(n, nbytes):
    """AR = RS + AG(shard) for ring algorithms."""
    hw = cm.TPU_V5E
    ar = cm.ring_all_reduce_time(nbytes, n, hw)
    rs = cm.ring_reduce_scatter_time(nbytes, n, hw)
    ag = cm.ring_all_gather_time(nbytes / n, n, hw)
    assert ar == pytest.approx(rs + ag, rel=1e-9)


@given(delay=st.floats(min_value=0.0, max_value=1e6),
       n=st.integers(min_value=2, max_value=4096))
@settings(max_examples=100, deadline=None)
def test_pingpong_fine_never_beats_bulk_without_overlap(delay, n):
    """On a machine with no async progression (the paper's HELIOS), bulk
    always wins — Fig 5b's HELIOS curve."""
    bulk, fine = cm.pingpong_times(n, delay, cm.HELIOS_BULLX)
    assert fine >= bulk - 1e-12


def test_crossover_ordering_matches_paper():
    """Qualitative reproduction of Fig 5b/6b: element-granular messaging
    never crosses at realistic constants (documented discrepancy,
    EXPERIMENTS.md §Paper-repro), tile-granular crossover exists on
    machines with async progression and not on HELIOS."""
    for hw in (cm.HECTOR_XE6, cm.JUQUEEN_BGQ, cm.TPU_V5E):
        assert math.isfinite(
            cm.crossover_compute_chunked(1 << 20, 8, hw=hw))
    assert math.isinf(
        cm.crossover_compute_chunked(1 << 20, 8, hw=cm.HELIOS_BULLX))


def test_roofline_terms():
    t = cm.roofline(hlo_flops=197e12, hlo_bytes=819e9,
                    collective_bytes=50e9, n_chips=1)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory", "collective")


def test_selective_pingpong_model():
    """Fig 6a: sending fewer elements shrinks the MPI/MDMP gap."""
    hw = cm.HECTOR_XE6
    gaps = []
    for sent in (1024, 128, 16):
        bulk, fine = cm.pingpong_times(1024, 0.0, hw, sent_elements=sent)
        gaps.append(fine - bulk)
    assert gaps[0] > gaps[1] > gaps[2]


# -- halo aggregation (the managed message-aggregation knob) -----------------


@given(rows=st.integers(min_value=8, max_value=4096),
       cols=st.integers(min_value=16, max_value=4096),
       k=st.integers(min_value=1, max_value=32))
@settings(max_examples=200, deadline=None)
def test_halo_sweep_time_positive(rows, cols, k):
    t = cm.halo_sweep_time(k, rows, cols)
    assert t > 0 and math.isfinite(t)


@given(rows=st.integers(min_value=8, max_value=4096),
       cols=st.integers(min_value=16, max_value=4096))
@settings(max_examples=200, deadline=None)
def test_halo_decision_never_worse_than_bulk(rows, cols):
    """The manager must never pick a k it predicts to be slower than the
    bulk (k=1) schedule."""
    d = cm.decide_halo_aggregation(rows, cols, 8)
    assert d.aggregated_sweep_s <= d.bulk_sweep_s * (1 + 1e-9)
    assert d.k in d.per_sweep_s
    assert d.per_sweep_s[d.k] == min(d.per_sweep_s.values())


# -- attention schedule (bulk gather vs ulysses a2a vs ring streaming) -------


@given(batch=st.integers(min_value=1, max_value=32),
       s_local=st.sampled_from([128, 1024, 8192, 65536]),
       heads=st.sampled_from([8, 32, 128]),
       n=st.sampled_from([2, 4, 8, 16]),
       causal=st.booleans())
@settings(max_examples=100, deadline=None)
def test_attention_decision_is_argmin(batch, s_local, heads, n, causal):
    """decide_attention_schedule must pick the schedule it predicts to be
    fastest, and every modeled time must be positive and finite."""
    d = cm.decide_attention_schedule(batch, s_local, heads, max(1, heads // 4),
                                     128, heads * 128, n, causal=causal)
    assert d.schedule in ("bulk", "ulysses", "ring")
    assert set(d.times_s) == {"bulk", "ulysses", "ring"}
    for t in d.times_s.values():
        assert t > 0 and math.isfinite(t)
    assert d.chosen_s <= min(d.times_s.values()) * (1 + 1e-9)


@given(n=st.sampled_from([4, 8, 16]))
@settings(max_examples=20, deadline=None)
def test_attention_ring_wins_long_context(n):
    """Long-context prefill is the ring's home turf: per-step KV transfer
    hides under per-block flash, while the bulk schedule's sequence gather
    grows with S — the crossover the PR 2 tentpole is built on."""
    d = cm.decide_attention_schedule(1, 65536 // n, 32, 8, 128, 4096, n,
                                     causal=False)
    assert d.schedule == "ring"
    assert d.times_s["ring"] < d.times_s["bulk"]


def test_attention_force_schedule():
    for s in ("bulk", "ulysses", "ring"):
        d = cm.decide_attention_schedule(1, 1024, 32, 8, 128, 4096, 8,
                                         force_schedule=s)
        assert d.schedule == s


def test_attention_tiny_seq_prefers_gather():
    """At tiny sequence lengths the per-step alpha of the ring dominates;
    the manager must keep a gather-style schedule."""
    d = cm.decide_attention_schedule(1, 64, 8, 8, 64, 512, 8, causal=True)
    assert d.times_s["ring"] >= min(d.times_s["bulk"],
                                    d.times_s["ulysses"]) * (1 - 1e-9)


def test_halo_aggregation_prefers_deep_halos_when_latency_dominates():
    """Small local blocks on a high-alpha machine: per-message latency
    dominates, so the manager must aggregate (k > 1) — the MatlabMPI /
    MDMP latency-dominance regime."""
    d = cm.decide_halo_aggregation(128, 514, 8, hw=cm.TPU_V5E)
    assert d.k > 1
    assert d.mode == "aggregated"
    assert d.predicted_speedup > 1.0


def test_halo_aggregation_force_bulk():
    d = cm.decide_halo_aggregation(128, 514, 8, force_k=1)
    assert d.k == 1 and d.mode == "bulk"
    assert d.aggregated_sweep_s == pytest.approx(d.bulk_sweep_s)


def test_halo_aggregation_respects_block_height():
    """k can never exceed the local block (the ghost trapezoid would
    swallow the whole shard)."""
    d = cm.decide_halo_aggregation(4, 514, 8, candidate_k=(1, 2, 4, 8))
    assert d.k <= 4


def test_halo_terms_structure():
    """alpha amortises k x; halo bytes per sweep stay constant; redundant
    flops grow with k."""
    c1, m1, f1 = cm.halo_sweep_terms(1, 256, 514)
    c8, m8, f8 = cm.halo_sweep_terms(8, 256, 514)
    hw = cm.TPU_V5E
    assert c1 - c8 == pytest.approx(2 * hw.alpha_s * (1 - 1 / 8))
    assert m8 < m1                     # k x fewer HBM round-trips
    assert f8 > f1                     # ghost trapezoid is extra compute
