"""Managed fault tolerance: the deterministic fault taxonomy, data-state
resume, straggler warmup after restore, the Young/Daly cadence decision,
corrupt-shard fallback, serve-replica drain/re-admit, and the elastic
tuner replay (host-side unit; the 8-device end-to-end lives in
tests/dist_suite/test_elastic.py)."""

import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import managed
from repro.core.faults import FaultError, FaultPlan, ReplicaDeath
from repro.core.tuner import ScheduleTuner, replan_for_mesh
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import MeshCtx
from repro.train.train_loop import (TrainLoop, TrainLoopConfig,
                                    build_train_step)


# ---------------------------------------------------------------------------
# FaultPlan grammar + one-shot semantics
# ---------------------------------------------------------------------------


def test_fault_plan_parse_and_fire():
    plan = FaultPlan.parse("slow@9:0.5, transient@6;corrupt@14:32")
    assert [(e.kind, e.step, e.arg) for e in plan.events] == [
        ("transient", 6, 0.0), ("slow", 9, 0.5), ("corrupt", 14, 32.0)]
    assert plan.fire("transient", 5) is None
    ev = plan.fire("transient", 6)
    assert ev is not None and ev.fired
    assert plan.fire("transient", 6) is None        # exactly once
    assert len(plan.unfired()) == 2
    with pytest.raises(AssertionError):
        FaultPlan.parse("meteor@3")
    hook = plan.train_hook()
    with pytest.raises(AssertionError):
        hook(14)                                    # corrupt needs ckpt_dir


# ---------------------------------------------------------------------------
# Train-loop faults (shared compiled step across tests)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def env():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    cfg = configs.get_reduced("granite-34b")
    model = Model(cfg, ctx)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=200)
    step_fn, pshard, bshard = build_train_step(model, opt_cfg, mesh)
    return model, opt_cfg, step_fn, pshard, bshard


def _data(model, seed=0):
    return SyntheticLMData(DataConfig(
        vocab_size=model.cfg.vocab_size, seq_len=64, global_batch=4,
        seed=seed))


def _loop(env, loop_cfg, *, seed=0, **kw):
    model, opt_cfg, step_fn, pshard, bshard = env
    return TrainLoop(step_fn, model, opt_cfg, _data(model, seed), loop_cfg,
                     pshard, bshard, **kw)


def test_resume_restores_data_pipeline_state(env, tmp_path):
    """A mid-run restart must replay the SAME loss trajectory as the
    uninterrupted run — optimizer state AND data-pipeline state both ride
    the checkpoint (the data state used to be dropped on resume)."""
    oracle = _loop(env, TrainLoopConfig(total_steps=10, ckpt_every=100,
                                        ckpt_dir=str(tmp_path / "o")))
    out_o = oracle.run(*oracle.init_state())
    faulted = _loop(env, TrainLoopConfig(total_steps=10, ckpt_every=4,
                                         ckpt_dir=str(tmp_path / "f")),
                    fault_plan=FaultPlan.parse("transient@6"))
    out_f = faulted.run(*faulted.init_state())
    assert out_f["restarts"] == 1
    by_step = {h["step"]: h["loss"] for h in out_f["history"]}  # last wins
    for h in out_o["history"]:
        np.testing.assert_array_equal(
            by_step[h["step"]], h["loss"],
            err_msg=f"trajectory diverged at step {h['step']}")


def test_resume_rejects_data_seed_mismatch(env, tmp_path):
    a = _loop(env, TrainLoopConfig(total_steps=4, ckpt_every=4,
                                   ckpt_dir=str(tmp_path)))
    a.run(*a.init_state())
    b = _loop(env, TrainLoopConfig(total_steps=8, ckpt_every=4,
                                   ckpt_dir=str(tmp_path)), seed=1)
    with pytest.raises(AssertionError, match="data seed mismatch"):
        b.resume_or_init()


def test_straggler_warmup_resets_after_restore(env, tmp_path):
    """Post-restore steps re-warm caches/compiles; judging them against
    the pre-fault EWMA flagged every recovery as a straggler (the warmup
    guard compared against the ORIGINAL start_step)."""
    state = {"faulted": False, "slow": set()}

    def hook(step):
        if step == 8 and not state["faulted"]:
            state["faulted"] = True
            state["slow"] = {4, 5}      # ckpt_every=4 -> restore to 4
            raise RuntimeError("injected node failure")
        if step in state["slow"]:
            state["slow"].discard(step)
            time.sleep(1.0)             # >> factor x EWMA

    # factor 5: the writer thread's D2H drain contends with the step on
    # this host (x2-3), which is NOT a straggler; the injected 1s stall
    # (x20+) is
    loop = _loop(env, TrainLoopConfig(total_steps=12, ckpt_every=4,
                                      ckpt_dir=str(tmp_path),
                                      straggler_factor=5.0),
                 fault_hook=hook)
    out = loop.run(*loop.init_state())
    assert out["restarts"] == 1 and out["step"] == 12
    assert out["stragglers"] == [], \
        "post-restore warmup steps flagged as stragglers"


def test_managed_cadence_decision(env, tmp_path):
    """managed_cadence turns ckpt_every into a decided knob: the interval
    comes from the Young/Daly model, lands in the decision log as
    op='ckpt_interval', and persists through the tuner."""
    managed.clear_decision_log()
    tuner = ScheduleTuner()
    loop = _loop(env, TrainLoopConfig(total_steps=8, ckpt_every=25,
                                      ckpt_dir=str(tmp_path),
                                      managed_cadence=True, mtbf_s=2.0),
                 tuner=tuner)
    out = loop.run(*loop.init_state())
    recs = [r for r in managed.decision_log() if r.op == "ckpt_interval"]
    assert recs, "managed cadence logged no ckpt_interval decision"
    assert out["ckpt_interval"] == recs[-1].chunks
    assert out["ckpt_interval"] < 25, \
        "a 2s MTBF must shorten the cadence vs the fixed-25 baseline"
    keys = [k for k in tuner.entries if k.startswith("ckpt_interval|")]
    assert keys and tuner.entries[keys[0]].chunks >= 1
    assert loop.ckpt_metrics.saves, "no instrumented saves recorded"


def test_corrupt_fault_falls_back_to_previous_step(env, tmp_path):
    """corrupt@k truncates the latest shard and dies; recovery must fall
    back to the previous committed step and still finish the run."""
    loop = _loop(env, TrainLoopConfig(total_steps=12, ckpt_every=3,
                                      ckpt_dir=str(tmp_path)),
                 fault_plan=FaultPlan.parse("corrupt@8"))
    out = loop.run(*loop.init_state())
    assert out["step"] == 12 and out["restarts"] == 1
    assert not loop.fault_plan.unfired()
    assert loop.ckpt_metrics.restores, "restore path never ran"
    # the fallback restored step 3 (latest=6 was corrupted), so steps 3..7
    # were re-executed
    assert out["steps_executed"] > 12


def test_transient_exhausts_retries(env, tmp_path):
    """max_retries still bounds the restart loop under a fault plan."""
    loop = _loop(env, TrainLoopConfig(total_steps=6, ckpt_every=100,
                                      ckpt_dir=str(tmp_path),
                                      max_retries=1),
                 fault_plan=FaultPlan.parse("transient@0;transient@0"))
    # two CONSECUTIVE failures (no successful step between) with
    # max_retries=1: the second exceeds the budget and propagates
    with pytest.raises(FaultError):
        loop.run(*loop.init_state())


# ---------------------------------------------------------------------------
# Serving: replica death -> drain -> re-admit, token-equal
# ---------------------------------------------------------------------------


def test_replica_death_drain_and_readmit():
    from repro.configs.base import ModelConfig
    from repro.parallel.sharding import infer_shardings
    from repro.serve.engine import ServeEngine

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(name="serve-faults", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, d_head=16, tp_multiple=4,
                      dtype="float32")
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    model = Model(cfg, ctx)
    params = jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s),
        model.init(jax.random.key(0)),
        infer_shardings(model.param_specs(), mesh))
    rng = np.random.default_rng(5)
    plens = [4, 8, 5, 12, 6, 10]
    n_new = 6
    prompts = [rng.integers(0, cfg.vocab_size - 1, size=p)
               .astype(np.int32) for p in plens]

    def engine(fault_plan=None):
        return ServeEngine(model, mesh, params, slots=4, max_seq=64,
                           page_size=8, schedule="continuous", chunk=4,
                           fault_plan=fault_plan)

    oracle_eng = engine()
    rids = [oracle_eng.submit(p, n_new) for p in prompts]
    oracle = oracle_eng.run()
    assert sorted(oracle) == rids

    dead = engine(FaultPlan.parse("replica_death@4"))
    for p in prompts:
        dead.submit(p, n_new)
    with pytest.raises(ReplicaDeath):
        dead.run()
    finished = dict(dead.results)
    drained = dead.drain()
    # the dead replica's pages and slots are fully evacuated
    assert dead.pt.pages_in_use == 0
    assert not dead.scheduler.active and not dead.scheduler.pending
    assert dead.scheduler._committed_pages == 0
    assert len(finished) + len(drained) == len(prompts)

    survivor = engine()
    for req, _prefix in drained:
        survivor.submit_request(req)
    cont = survivor.run()
    # greedy chains: prefill-replayed continuations + already-finished
    # results must be token-equal to the no-fault oracle, per request
    for req, prefix in drained:
        got = np.concatenate([np.asarray(prefix, np.int32),
                              np.asarray(cont[req.rid], np.int32)])
        np.testing.assert_array_equal(got, oracle[req.rid],
                                      err_msg=f"rid {req.rid}")
    for rid, toks in finished.items():
        np.testing.assert_array_equal(toks, oracle[rid],
                                      err_msg=f"rid {rid} (finished)")


# ---------------------------------------------------------------------------
# Elastic re-planning (host-side unit; devices not needed)
# ---------------------------------------------------------------------------


def test_replan_for_mesh_replays_winners():
    tuner = ScheduleTuner()
    halo = tuner.decide_halo("x", 4, 1024, 256)
    # a measured comparison picked aggregated k=4 on the old topology
    tuner.record(halo.key, "aggregated", 4, 1e-3)
    tuner.record(halo.key, "bulk", 1, 2e-3)
    tuner.decide_ckpt("mesh", 4, 1 << 20, 0.05, mtbf_s=60.0)
    managed.clear_decision_log()
    recs = replan_for_mesh(tuner, {"x": 8, "mesh": 8}, step_s=0.05,
                           mtbf_s=60.0)
    ops = {r["op"]: r for r in recs}
    assert set(ops) == {"halo_jacobi", "ckpt_interval"}
    r = ops["halo_jacobi"]
    assert (r["old_n"], r["new_n"]) == (4, 8)
    assert "x8" in r["new_key"] and "1024" not in r["new_key"].split("|")[1]
    new = tuner.entries[r["new_key"]]
    assert (new.mode, new.chunks) == ("aggregated", 4)   # winner replayed
    assert new.measured_s == {}       # measurements do NOT transfer
    assert tuner.entries[halo.key].measured_s            # old entry intact
    # the replay itself is in the decision trail (old winner pinned)
    logged = {rec.op for rec in managed.decision_log()}
    assert {"halo_aggregation", "ckpt_interval"} <= logged
