"""Tier-1 pipeline-subsystem tests (single device; the pod axis has size 1
here — the 8-way versions live in tests/dist_suite/test_pipeline.py).

Covers the stage-partition remainder fix, the lock-step schedule builder's
invariants (incl. the O(n_stage)-vs-O(M) stash contrast), loss+grad
equivalence of all three schedules against the sequential oracle, the
grad-accumulation contract, and the managed decision / tuner / region
units for the pipeline knob.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import managed, overlap, region, tuner
from repro.parallel import pipeline


# -- stage partitioning (the remainder bugfix) -------------------------------


def test_chunk_bounds_distributes_remainder():
    """5 layers over 2 stages: stage 0 gets 3, stage 1 gets 2 — the seed
    code silently dropped the last n_layers % n_stage layers."""
    assert pipeline.chunk_bounds(5, 2, 0) == (0, 3)
    assert pipeline.chunk_bounds(5, 2, 1) == (3, 2)


@pytest.mark.parametrize("n_layers,n_chunks",
                         [(5, 2), (7, 3), (2, 8), (9, 4), (16, 8), (3, 3)])
def test_chunk_bounds_cover_all_layers(n_layers, n_chunks):
    seen = []
    for q in range(n_chunks):
        lo, per = pipeline.chunk_bounds(n_layers, n_chunks, q)
        seen.extend(range(lo, lo + per))
        assert per <= pipeline.max_chunk_layers(n_layers, n_chunks)
    assert seen == list(range(n_layers))


def test_composed_stages_match_sequential_oracle():
    """n_layers=5 over 2 stages: applying stage 0's slice then stage 1's
    == the sequential stack (regression for the dropped-layer bug)."""
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(5, 8, 8)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))

    def layer_fn(xc, w):
        return jnp.tanh(xc @ w)

    want = x
    for i in range(5):
        want = layer_fn(want, ws[i])

    got = x
    for stage in range(2):
        cp, per = pipeline.slice_chunk_params(ws, 5, 2, stage)
        got = pipeline.masked_chunk_apply(layer_fn, cp, per, got)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# -- schedule builder --------------------------------------------------------


@pytest.mark.parametrize("name,m,s,v", [
    ("gpipe", 4, 2, 1), ("gpipe", 8, 4, 1), ("1f1b", 4, 2, 1),
    ("1f1b", 16, 8, 1), ("interleaved", 8, 4, 2), ("interleaved", 8, 2, 3),
])
def test_build_schedule_invariants(name, m, s, v):
    """The builder self-checks tightness / lane collisions; verify the
    table is complete: every (mb, chunk) appears once per lane."""
    sch = pipeline.build_schedule(name, m, s, v)
    n_virtual = s * sch.virtual
    for mb_tab, ch_tab in ((sch.f_mb, sch.f_chunk), (sch.b_mb, sch.b_chunk)):
        units = sorted((int(mb), int(q))
                       for mb, q in zip(mb_tab.ravel(), ch_tab.ravel())
                       if mb >= 0)
        assert units == sorted((mb, q) for mb in range(m)
                               for q in range(n_virtual))
    assert (sch.f_slot >= 0).sum() == m * n_virtual / s * s


def test_1f1b_stash_is_o_n_stage_not_o_m():
    """The 1F1B memory claim: peak live activations per stage stay O(S)
    while GPipe's grow with the microbatch count."""
    s = 4
    for m in (8, 16, 32, 64):
        assert pipeline.build_schedule("gpipe", m, s).n_stash == m
        assert pipeline.build_schedule("1f1b", m, s).n_stash <= 2 * s
    assert pipeline.build_schedule("interleaved", 32, s, 2).n_stash <= \
        2 * 2 * s + s


def test_1f1b_fewer_ticks_than_gpipe():
    for m, s in ((8, 4), (16, 8)):
        assert pipeline.build_schedule("1f1b", m, s).ticks < \
            pipeline.build_schedule("gpipe", m, s).ticks


def test_interleaved_requires_divisible_microbatches():
    with pytest.raises(ValueError):
        pipeline.build_schedule("interleaved", 6, 4, 2)


# -- executor vs sequential oracle (pod axis size 1) -------------------------


def _toy_problem():
    rng = np.random.default_rng(1)
    n_layers, d, m, b = 5, 8, 4, 4
    ws = jnp.asarray(rng.normal(size=(n_layers, d, d)).astype(np.float32)
                     * 0.3)
    xs = jnp.asarray(rng.normal(size=(m, b, d)).astype(np.float32))
    tg = jnp.asarray(rng.normal(size=(m, b, d)).astype(np.float32))
    return n_layers, d, m, b, ws, xs, tg


def _layer_fn(x, w):
    return jnp.tanh(x @ w)


@pytest.mark.parametrize("name,virtual", [("gpipe", 1), ("1f1b", 1),
                                          ("interleaved", 2)])
def test_pipeline_matches_sequential_oracle(name, virtual):
    """All three schedules produce the sequential loss AND grads."""
    n_layers, d, m, b, ws, xs, tg = _toy_problem()
    n_virtual = 1 * virtual           # one stage in tier-1

    def oracle(p):
        losses = []
        for mb in range(m):
            x = xs[mb]
            for i in range(n_layers):
                x = _layer_fn(x, p[i])
            losses.append(jnp.mean((x - tg[mb]) ** 2))
        return jnp.mean(jnp.stack(losses))

    want_loss, want_g = jax.value_and_grad(oracle)(ws)

    sched = pipeline.build_schedule(name, m, 1, virtual)

    def chunk_fn(p, q, mb, x):
        x = jnp.where(q == 0, xs[mb], x)
        cp, per = pipeline.slice_chunk_params(p, n_layers, n_virtual, q)
        return pipeline.masked_chunk_apply(_layer_fn, cp, per, x)

    def loss_fn(p, y, mb):
        return jnp.mean((y - tg[mb]) ** 2)

    loss, grads = jax.jit(lambda p: pipeline.pipeline_value_and_grad(
        chunk_fn, loss_fn, p,
        jax.ShapeDtypeStruct((b, d), np.float32), sched, "pod"))(ws)
    np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grads), np.asarray(want_g),
                               rtol=2e-5, atol=1e-7)


# -- grad accumulation contract (overlap.py bugfix) --------------------------


def test_grad_accumulate_contract_vs_hand_loop():
    """mean=True (default) returns (mean_loss, MEAN grads); mean=False
    returns the summed accumulator — asserted against a hand-rolled
    loop (the docstring used to promise sums while returning means)."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    xs = jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))

    def step_fn(mb):
        def f(wv):
            return jnp.sum((wv * mb) ** 2)
        return jax.value_and_grad(f)(w)

    losses, grads = [], []
    for i in range(3):
        l, g = step_fn(xs[i])
        losses.append(float(l))
        grads.append(np.asarray(g))
    want_mean_loss = np.mean(losses)
    want_sum_g = np.sum(grads, axis=0)

    loss, g = jax.jit(overlap.grad_accumulate(step_fn, 3))(xs)
    np.testing.assert_allclose(float(loss), want_mean_loss, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g), want_sum_g / 3, rtol=1e-6)

    loss, g = jax.jit(overlap.grad_accumulate(step_fn, 3, mean=False))(xs)
    np.testing.assert_allclose(float(loss), want_mean_loss, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g), want_sum_g, rtol=1e-6)


# -- instrumentation (instrument.py binder-aliasing bugfix) ------------------


def test_instrument_literal_operand_keeps_binder_alignment():
    """A scan whose eqn carries a Literal operand (the 0.0 init) BEFORE the
    tracked arrays: binders must pair with the unfiltered invars (the
    literal-bound carry binder is skipped), not slide onto the wrong outer
    operand.  The body reads only ``a`` — under the old filtered-operand
    mapping, ``b``'s tracking attached to the carry binder and counted a
    phantom inner read.  (Lives here, not test_substrates.py, because
    that module importorskips on hypothesis.)"""
    from jax import lax

    from repro.core import instrument

    def body_region(a, b):
        def body(c, xs):
            xa, _ = xs
            return c * 2.0 + jnp.sum(xa), None
        out, _ = lax.scan(body, 0.0, (a, b))
        return out

    rep = instrument.analyze_region(body_region, jnp.ones(3), jnp.ones(3),
                                    tracked_args=[0, 1], labels=["a", "b"])
    assert rep.records["a"].reads == 2       # the scan eqn + the body
    assert rep.records["b"].reads == 1       # the scan eqn ONLY


def test_instrument_cond_skips_branch_index_operand():
    """cond's leading invar is the branch index, not a branch argument —
    binders must align against the remaining operands."""
    from jax import lax

    from repro.core import instrument

    def body_region(a, b):
        return lax.cond(jnp.sum(a) > 0.0,
                        lambda ops: ops[0] * 2.0,
                        lambda ops: ops[0] + 1.0, (b,))

    rep = instrument.analyze_region(body_region, jnp.ones(3), jnp.ones(3),
                                    tracked_args=[0, 1], labels=["a", "b"])
    assert rep.records["b"].reads >= 2       # the cond eqn + a branch body


def test_instrument_while_loop_binder_alignment():
    """while_loop's two sub-jaxprs bind DIFFERENT operand subsets
    (cond_consts + carry vs body_consts + carry) — zipping both against
    the full invars would pair the cond jaxpr's carry binders with body
    consts and count phantom reads of tracked body operands."""
    from jax import lax

    from repro.core import instrument

    def body_region(a, b):
        def cond_f(c):
            return c[0] < 3
        def body_f(c):
            i, acc = c
            return i + 1, acc + jnp.sum(a) + jnp.sum(b)
        _, out = lax.while_loop(cond_f, body_f, (0, jnp.float32(0.0)))
        return out

    rep = instrument.analyze_region(body_region, jnp.ones(3), jnp.ones(3),
                                    tracked_args=[0, 1], labels=["a", "b"])
    # one read at the while eqn + one in the body; the cond predicate
    # (i < 3) must NOT count as a read of a tracked array
    assert rep.records["a"].reads == 2
    assert rep.records["b"].reads == 2


# -- the managed decision ----------------------------------------------------


def test_decide_pipeline_is_argmin():
    d = cm.decide_pipeline_schedule(4, 1e-3, 1e6, n_layers=16)
    assert d.schedule in ("gpipe", "1f1b", "interleaved")
    assert f"{d.schedule}:{d.n_micro}:{d.virtual}" in d.times_s
    assert d.chosen_s <= min(d.times_s.values()) * (1 + 1e-9)
    for t in d.times_s.values():
        assert t > 0 and np.isfinite(t)


def test_decide_pipeline_gpipe_bubble_formula():
    d = cm.decide_pipeline_schedule(4, 1e-3, 1e6, force_schedule="gpipe",
                                    force_micro=8)
    assert d.bubble_frac == pytest.approx((4 - 1) / (8 + 4 - 1))


def test_decide_pipeline_memory_cap_retires_gpipe():
    """GPipe stashes the whole batch regardless of M; a stash cap below
    that retires every gpipe variant and the manager falls back to the
    O(S)-memory schedules."""
    d = cm.decide_pipeline_schedule(4, 1e-3, 1e9, n_layers=16,
                                    stash_cap_bytes=0.5e9)
    assert d.schedule in ("1f1b", "interleaved")
    assert not any(k.startswith("gpipe") for k in d.times_s)
    assert d.stash_bytes <= 0.5e9 * 2 * 4   # slots bounded by 2S


def test_decide_pipeline_alpha_dominated_prefers_fewest_ticks():
    """With negligible compute the tick count (per-message alpha) decides:
    1f1b has the fewest ticks of the three timetables."""
    d = cm.decide_pipeline_schedule(8, 1e-9, 1e2, n_layers=16)
    assert d.schedule == "1f1b"


def test_resolve_pipeline_schedule_logs_and_forces():
    managed.clear_decision_log()
    d = managed.resolve_pipeline_schedule("pod", 4, 1e-3, 1e6, n_layers=16)
    rec = managed.decision_log()[-1]
    assert rec.op == "pipeline_schedule"
    assert rec.mode == d.schedule and rec.chunks == d.n_micro
    # bulk pins the unmanaged gpipe baseline; interleaved pins 1f1b
    assert managed.resolve_pipeline_schedule(
        "pod", 4, 1e-3, 1e6, mode="bulk").schedule == "gpipe"
    assert managed.resolve_pipeline_schedule(
        "pod", 4, 1e-3, 1e6, mode="interleaved").schedule == "1f1b"
    assert managed.resolve_pipeline_schedule(
        "pod", 4, 1e-3, 1e6, schedule="interleaved",
        n_micro=8, virtual=2).n_micro == 8


def test_tuner_decide_pipeline_seeds_and_adapts():
    t = tuner.ScheduleTuner()
    e = t.decide_pipeline("pod", 4, 16, (8, 128, 64), 1e-3, 1 << 20)
    assert e.mode in ("gpipe", "1f1b", "interleaved")
    # measured feedback overrides the seed (iteration k -> k+1)
    t.record(e.key, "gpipe", 8, 2e-3)
    t.record(e.key, "interleaved", 8, 1e-3)
    assert t.entries[e.key].mode == "interleaved"
    assert t.entries[e.key].chunks == 8
    # the trial sweep walks PIPELINE_CANDIDATES
    seen = set()
    while True:
        trial = t.next_trial(e.key)
        if trial is None:
            break
        seen.add(trial)
        t.record(e.key, trial[0], trial[1], 5e-3)
    assert seen | {("gpipe", 8), ("interleaved", 8)} >= \
        set(tuner.ScheduleTuner.PIPELINE_CANDIDATES)


def test_region_pipeline_declaration_plans_schedule():
    r = region.CommRegion("train", axis_sizes={"pod": 4})
    r.pipeline("stage_boundary", axis="pod", n_layers=16,
               batch_shape=(8, 128, 64), dtype=np.float32,
               batch_fwd_s=1e-3)

    def body(x):
        return jnp.tanh(x) @ x.T

    plan = r.plan(body, jnp.ones((8, 8)))
    entry = plan.entries["stage_boundary"]
    assert entry.mode in ("gpipe", "1f1b", "interleaved")
    assert plan.schedule_for("stage_boundary") == entry.mode
    assert entry.chunks >= 1                      # the microbatch count M
    assert entry.predicted_interleaved_s <= entry.predicted_bulk_s * (1 + 1e-9)
