"""Managed MoE dispatch validation (tier-1, single device).

Four layers of oracles:
  * dispatch bookkeeping — capacity round-up (the seed's floor dropped
    tokens at capacity_factor=1.0 balanced), and the gather/combine
    round-trip == gate-weighted identity on kept tokens with exactly
    zero contribution from dropped ones (numpy oracle + hypothesis
    property over arbitrary (t, E, top_k, capacity));
  * kernel — grouped-expert GEMM Pallas (interpret) == jnp masked
    einsum bit-exact, including padded capacity rows holding garbage,
    with matching gradients through the custom VJP;
  * model — the three dispatch schedules (bulk / stream / dense) agree
    on a degenerate axis for both layouts (multi-rank equivalence lives
    in tests/dist_suite/test_moe.py);
  * the managed decision — cost model, resolver trail, tuner seed /
    measured override / persistence, CommRegion declaration, and the
    instrumented routing statistics that re-resolve the capacity factor.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import cost_model as cm
from repro.core import instrument, managed
from repro.kernels import grouped_matmul as gm
from repro.models import moe
from repro.moe.dispatch import (capacity_for, combine_from_buffers,
                                dispatch_indices, expert_counts,
                                gather_to_buffers)
from repro.parallel.sharding import MeshCtx, smap


# ---------------------------------------------------------------------------
# Dispatch bookkeeping
# ---------------------------------------------------------------------------


def test_capacity_for_rounds_up():
    """ceil, not floor: t=10, K=1, E=4, cf=1.0 -> C=3; the seed's
    int(10 * 1 / 4 * 1.0) = 2 dropped tokens under balanced routing."""
    e_cfg = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8,
                      capacity_factor=1.0)
    assert capacity_for(10, e_cfg) == 3
    assert int(10 * 1 / 4 * 1.0) == 2          # what the seed computed
    # balanced-ish routing with max load 3 fits: nothing drops
    top = jnp.asarray(np.array([[0], [1], [2], [3], [0], [1], [2], [3],
                                [0], [1]], np.int32))
    _, _, keep, _ = dispatch_indices(top, 4, capacity_for(10, e_cfg))
    np.testing.assert_array_equal(np.asarray(keep), 1.0)
    # override: the managed decision's re-picked cf flows through
    assert capacity_for(10, e_cfg, 2.0) == 5


def _roundtrip_oracle(x, gates, top_idx, n_experts, capacity):
    """Independent numpy oracle of the GShard capacity semantics: entry
    (t, k) is kept iff fewer than C earlier entries (stable expert-major
    order) routed to its expert; y[t] = sum_kept gate * x[t]."""
    t, k = top_idx.shape
    flat_e = top_idx.reshape(-1)
    order = np.argsort(flat_e, kind="stable")
    fill = np.zeros(n_experts, np.int64)
    y = np.zeros_like(x)
    kept_mask = np.zeros(t * k, bool)
    for pos in order:
        e = flat_e[pos]
        if fill[e] < capacity:
            fill[e] += 1
            kept_mask[pos] = True
            y[pos // k] += gates.reshape(-1)[pos] * x[pos // k]
    return y, kept_mask


def _check_roundtrip(x, gates, top_idx, n_experts, capacity):
    dest, tok, keep, order = dispatch_indices(
        jnp.asarray(top_idx), n_experts, capacity)
    buffers = gather_to_buffers(jnp.asarray(x), dest, tok, keep,
                                n_experts, capacity)
    y = combine_from_buffers(buffers, dest, tok, keep, jnp.asarray(gates),
                             order, x.shape[0])
    want, kept_mask = _roundtrip_oracle(x, gates, top_idx, n_experts,
                                        capacity)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-6)
    # keep flags agree with the oracle's capacity semantics
    # (dispatch_indices' keep is in expert-sorted order; map it back)
    inv = np.empty_like(np.asarray(order))
    inv[np.asarray(order)] = np.arange(len(inv))
    np.testing.assert_array_equal(np.asarray(keep)[inv].astype(bool),
                                  kept_mask)
    # counts consistent with keep
    counts = expert_counts(jnp.asarray(top_idx), n_experts, capacity)
    assert int(np.sum(np.asarray(counts))) == int(kept_mask.sum())


@pytest.mark.parametrize("seed,t,e,k,cap", [
    (0, 16, 4, 2, 3),      # overflow everywhere
    (1, 8, 8, 1, 1),       # tight capacity
    (2, 32, 4, 4, 40),     # capacity exceeds load: nothing drops
    (3, 5, 3, 2, 2),
])
def test_dispatch_roundtrip_cases(seed, t, e, k, cap):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, 6)).astype(np.float32)
    gates = rng.uniform(0.1, 1.0, size=(t, k)).astype(np.float32)
    top_idx = rng.integers(0, e, size=(t, k)).astype(np.int32)
    _check_roundtrip(x, gates, top_idx, e, cap)


def test_dispatch_roundtrip_property():
    """Hypothesis property: gather_to_buffers ∘ combine_from_buffers ==
    gate-weighted identity on kept tokens and exactly zero contribution
    from dropped tokens, for arbitrary (t, E, top_k, capacity) including
    capacity-overflow cases."""
    hyp = pytest.importorskip("hypothesis")
    hnp = pytest.importorskip("hypothesis.extra.numpy")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(deadline=None, max_examples=50)
    @hyp.given(st.data(), st.integers(1, 24), st.integers(1, 8),
               st.integers(1, 4), st.integers(1, 9))
    def run(data, t, e, k, cap):
        k = min(k, e)
        x = data.draw(hnp.arrays(np.float32, (t, 4),
                                 elements=st.floats(-4, 4, width=32)))
        gates = data.draw(hnp.arrays(np.float32, (t, k),
                                     elements=st.floats(0, 1, width=32)))
        top_idx = data.draw(hnp.arrays(np.int32, (t, k),
                                       elements=st.integers(0, e - 1)))
        _check_roundtrip(x, gates, top_idx, e, cap)

    run()


# ---------------------------------------------------------------------------
# Grouped-expert GEMM kernel
# ---------------------------------------------------------------------------


def _gemm_operands(seed, G, C, D, F, E, garbage=True):
    rng = np.random.default_rng(seed)
    h = rng.normal(size=(G, C, D)).astype(np.float32)
    valid = rng.integers(0, C + 1, size=G).astype(np.int32)
    valid[0] = 0
    valid[-1] = C
    if garbage:
        # rows past the valid count may hold ANYTHING (the engines mask)
        rows = np.arange(C)
        h = np.where(rows[None, :, None] < valid[:, None, None], h,
                     1e3 * rng.normal(size=h.shape)).astype(np.float32)
    w1 = rng.normal(size=(E, D, F)).astype(np.float32) * 0.1
    w1g = rng.normal(size=(E, D, F)).astype(np.float32) * 0.1
    w2 = rng.normal(size=(E, F, D)).astype(np.float32) * 0.1
    return (jnp.asarray(h), jnp.asarray(w1), jnp.asarray(w1g),
            jnp.asarray(w2), jnp.asarray(valid))


@pytest.mark.parametrize("mlp", ["swiglu", "relu2"])
@pytest.mark.parametrize("G,C,D,F,E", [
    (4, 16, 8, 12, 4),       # one group per expert
    (8, 32, 8, 16, 2),       # (expert, src-rank) grouping: gpe=4
    (3, 256, 8, 8, 3),       # multi-block capacity walk (blk_c=128)
])
def test_grouped_gemm_engines_bit_exact(mlp, G, C, D, F, E):
    h, w1, w1g, w2, valid = _gemm_operands(G * 7 + C, G, C, D, F, E)
    w1g_in = w1g if gm.gated(mlp) else None
    o_jnp = gm.grouped_expert_ffn(h, w1, w1g_in, w2, valid, mlp=mlp,
                                  engine="jnp")
    o_pal = gm.grouped_expert_ffn(h, w1, w1g_in, w2, valid, mlp=mlp,
                                  engine="pallas")
    np.testing.assert_array_equal(np.asarray(o_jnp), np.asarray(o_pal))
    # padded capacity rows are EXACT zeros in both engines
    rows = np.arange(C)
    pad = rows[None, :, None] >= np.asarray(valid)[:, None, None]
    np.testing.assert_array_equal(np.asarray(o_jnp)[np.broadcast_to(
        pad, o_jnp.shape)], 0.0)


def test_grouped_gemm_matches_plain_ffn_when_full():
    """valid == C on zero-padded-free buffers reduces to the plain dense
    expert FFN einsum."""
    G, C, D, F = 4, 8, 6, 10
    h, w1, w1g, w2, _ = _gemm_operands(3, G, C, D, F, G, garbage=False)
    valid = jnp.full((G,), C, jnp.int32)
    got = gm.grouped_expert_ffn(h, w1, w1g, w2, valid, mlp="swiglu",
                                engine="jnp")
    u = jnp.einsum("ecd,edf->ecf", h, w1)
    g = jnp.einsum("ecd,edf->ecf", h, w1g)
    want = jnp.einsum("ecf,efd->ecd", jax.nn.silu(u) * g, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_grouped_gemm_grads(engine):
    """Gradients flow through both engines (the Pallas path's custom VJP
    recomputes through the jnp engine) and match the masked reference."""
    G, C, D, F, E = 4, 16, 8, 12, 4
    h, w1, w1g, w2, valid = _gemm_operands(11, G, C, D, F, E)

    def loss(hh, a, b, c):
        return jnp.sum(gm.grouped_expert_ffn(hh, a, b, c, valid,
                                             mlp="swiglu",
                                             engine=engine) ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(h, w1, w1g, w2)

    def ref_loss(hh, a, b, c):
        rows = jnp.arange(C)
        hm = jnp.where(rows[None, :, None] < valid[:, None, None], hh, 0.0)
        u = jnp.einsum("ecd,edf->ecf", hm, a,
                       preferred_element_type=jnp.float32)
        g = jnp.einsum("ecd,edf->ecf", hm, b,
                       preferred_element_type=jnp.float32)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(u) * g, c,
                         preferred_element_type=jnp.float32)
        return jnp.sum(out ** 2)

    want = jax.grad(ref_loss, argnums=(0, 1, 2, 3))(h, w1, w1g, w2)
    for g_, w_, nm in zip(grads, want, "h123"):
        np.testing.assert_allclose(np.asarray(g_), np.asarray(w_),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"d{nm} ({engine})")


# ---------------------------------------------------------------------------
# Model blocks: the three schedules agree (degenerate axis; 8-rank
# equivalence lives in tests/dist_suite/test_moe.py)
# ---------------------------------------------------------------------------


def _block_cfg(impl, disp, g=0, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=64, tp_multiple=1,
        dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=cf, impl=impl, dispatch=disp,
                      dispatch_g=g))


@pytest.fixture(scope="module")
def block_inputs():
    rng = np.random.default_rng(0)
    E, D, F = 4, 16, 32
    x = jnp.asarray(rng.normal(size=(2, 8, D)).astype(np.float32))
    params = {
        "w_router": jnp.asarray(rng.normal(size=(D, E))
                                .astype(np.float32)),
        "w1": jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32)
                          * 0.1),
        "w1_gate": jnp.asarray(rng.normal(size=(E, D, F))
                               .astype(np.float32) * 0.1),
        "w2": jnp.asarray(rng.normal(size=(E, F, D)).astype(np.float32)
                          * 0.1),
    }
    return x, params


@pytest.mark.parametrize("impl", ["ep_a2a", "expert_tp"])
def test_block_schedules_agree(impl, block_inputs):
    x, params = block_inputs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")
    fn = (moe.moe_block_ep if impl == "ep_a2a"
          else moe.moe_block_expert_tp)
    outs = {}
    for disp in ("bulk", "stream", "dense", "auto"):
        cfg = _block_cfg(impl, disp)
        run = jax.jit(smap(
            lambda xx, pp, cfg=cfg: fn(xx, pp, cfg, ctx)[0], mesh,
            in_specs=(P(None, "model", None), P()),
            out_specs=P(None, "model", None)))
        outs[disp] = np.asarray(run(x, params))
    for disp in ("stream", "dense", "auto"):
        np.testing.assert_allclose(outs[disp], outs["bulk"], rtol=1e-5,
                                   atol=1e-6, err_msg=f"{impl} {disp}")


def test_dense_is_capacity_free_on_degenerate_axis(block_inputs):
    """schedule='dense' honors the never-drops contract even at tp=1: at
    a starved capacity factor the capacity path drops tokens, the dense
    path matches the unlimited-capacity reference exactly."""
    x, params = block_inputs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx.from_mesh(mesh, mdmp_mode="bulk")

    def run(disp, cf):
        cfg = _block_cfg("ep_a2a", disp, cf=cf)
        fn = jax.jit(smap(
            lambda xx, pp, cfg=cfg: moe.moe_block_ep(xx, pp, cfg, ctx)[0],
            mesh, in_specs=(P(None, "model", None), P()),
            out_specs=P(None, "model", None)))
        return np.asarray(fn(x, params))

    unlimited = run("bulk", 64.0)            # capacity covers everything
    dense = run("dense", 0.25)               # starved cf: dense ignores it
    starved = run("bulk", 0.25)              # ... the capacity path drops
    np.testing.assert_allclose(dense, unlimited, rtol=1e-5, atol=1e-6)
    assert np.abs(starved - unlimited).max() > 1e-3


# ---------------------------------------------------------------------------
# The managed decision + instrumentation units
# ---------------------------------------------------------------------------


def test_decide_moe_dispatch_model():
    # production point (moonshot over EP16, v5e): the stream hides the
    # capacity-buffer wire under the grouped-GEMM compute
    d = cm.decide_moe_dispatch(8192, 2048, 64, 6, 1408, 16, mults=3,
                               dtype_bytes=2, capacity_factor=1.25)
    assert d.schedule == "stream" and d.predicted_speedup > 1.0
    assert f"{d.schedule}:{d.g}" in d.times_s
    # over-provisioned capacity balloons the a2a bytes AND the padded
    # rows: the capacity-free dense fallback crosses over
    dd = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8, dtype_bytes=4,
                                capacity_factor=8.0)
    assert dd.times_s["dense:1"] < dd.times_s["bulk:1"]
    # degenerate axis: nothing crosses a link, bulk capacity path wins
    d1 = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 1)
    assert d1.schedule == "bulk"
    # pinning
    df = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                force_schedule="stream", force_g=4)
    assert (df.schedule, df.g) == ("stream", 4)
    df2 = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                 force_schedule="dense", force_g=4)
    assert (df2.schedule, df2.g) == ("dense", 1)


def test_decide_moe_dispatch_capacity_adaptation():
    # no measurement: the declared static guess stands
    d0 = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                capacity_factor=1.25)
    assert d0.capacity_factor == 1.25 and d0.drop_frac == 0.0
    # skewed routing measured: cf grows to the smallest covering
    # candidate (drop-free) — and the capacity ceil matches
    du = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                capacity_factor=1.25,
                                measured_imbalance=3.2)
    assert du.capacity_factor == 4.0 and du.drop_frac == 0.0
    assert du.capacity == cm.moe_capacity(1024, 2, 8, 4.0)
    # uniform routing measured: the over-provisioned guess SHRINKS
    dd = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                capacity_factor=8.0,
                                measured_imbalance=1.1)
    assert dd.capacity_factor < 8.0
    # imbalance beyond every candidate: the capacity path reports a
    # residual drop — and the free choice escapes to the capacity-FREE
    # dense fallback, which never drops
    dr = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                capacity_factor=1.0,
                                measured_imbalance=100.0,
                                force_schedule="bulk")
    assert dr.drop_frac > 0.0
    dfree = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                   capacity_factor=1.0,
                                   measured_imbalance=100.0)
    assert dfree.schedule == "dense" and dfree.drop_frac == 0.0
    # a bare measured drop rate escalates past the declared cf
    de = cm.decide_moe_dispatch(1024, 256, 8, 2, 128, 8,
                                capacity_factor=1.25,
                                measured_drop_rate=0.1)
    assert de.capacity_factor > 1.25


def test_resolve_moe_dispatch_trail():
    managed.clear_decision_log()
    d = managed.resolve_moe_dispatch("model", 8, 1024, 256, 8, 2, 128,
                                     capacity_factor=1.25)
    rec = managed.decision_log()[-1]
    assert rec.op == "moe_dispatch"
    assert rec.mode == d.schedule and rec.chunks == d.g
    assert rec.nbytes == d.a2a_bytes
    # ambient bulk mode pins the unmanaged baseline
    with managed.use_config(managed.MDMPConfig(mode="bulk")):
        db = managed.resolve_moe_dispatch("model", 8, 1024, 256, 8, 2,
                                          128)
    assert db.schedule == "bulk"
    # ambient interleaved mode pins the always-stream schedule
    with managed.use_config(managed.MDMPConfig(mode="interleaved")):
        di = managed.resolve_moe_dispatch("model", 8, 1024, 256, 8, 2,
                                          128)
    assert di.schedule == "stream"
    # an EXPLICIT schedule wins over the ambient mode (cfg.moe.dispatch
    # precedence, same contract as the pipeline knob)
    with managed.use_config(managed.MDMPConfig(mode="interleaved")):
        dx = managed.resolve_moe_dispatch("model", 8, 1024, 256, 8, 2,
                                          128, schedule="dense")
    assert dx.schedule == "dense"


def test_tuner_moe(tmp_path):
    from repro.core.tuner import ScheduleTuner
    path = str(tmp_path / "tuner.json")
    t = ScheduleTuner(path=path)
    e = t.decide_moe("model", 8, 1024, 256, 8, 2, 128,
                     dtype_str="float32", dtype_bytes=4)
    assert e.mode in ("bulk", "stream", "dense")
    assert t.next_trial(e.key) == ScheduleTuner.MOE_CANDIDATES[0]
    # measured override: dense wins
    t.record(e.key, "bulk", 1, 5e-3)
    t.record(e.key, "stream", 2, 6e-3)
    t.record(e.key, "dense", 1, 2e-3)
    assert (t.entries[e.key].mode, t.entries[e.key].chunks) == ("dense", 1)
    t.save()
    t2 = ScheduleTuner(path=path)
    assert t2.entries[e.key].mode == "dense"


def test_comm_region_moe_declaration():
    from repro.core.region import CommRegion
    region = CommRegion("moe", axis_sizes={"model": 8})
    region.moe("dispatch", axis="model", tokens_local=1024, d_model=256,
               n_experts=8, top_k=2, d_ff_expert=128, dtype=jnp.bfloat16,
               capacity_factor=1.25)
    plan = region.plan(lambda x: x + 1, np.zeros(4, np.float32))
    assert plan.schedule_for("dispatch") in ("bulk", "stream", "dense")
    assert plan.chunks_for("dispatch") >= 1
    cap = cm.moe_capacity(1024, 2, 8, 1.25)
    assert plan.entries["dispatch"].spec.nbytes == 8 * cap * 256 * 2


def test_routing_stats_exact():
    # 4 tokens top-2 over 4 experts, capacity 2:
    # loads = [4, 2, 1, 1]; kept = [2, 2, 1, 1] -> drop 2/8, occ 6/8
    top = np.array([[0, 1], [0, 1], [0, 2], [0, 3]], np.int32)
    stats = instrument.moe_routing_stats(jnp.asarray(top), 4, 2)
    np.testing.assert_array_equal(np.asarray(stats["histogram"]),
                                  [4.0, 2.0, 1.0, 1.0])
    assert np.isclose(float(stats["drop_rate"]), 0.25)
    assert np.isclose(float(stats["occupancy"]), 0.75)
    assert np.isclose(float(stats["imbalance"]), 2.0)
    instrument.clear_routing_log()
    rec = instrument.capture_routing("layer0", top, 4, 2)
    assert instrument.routing_log() == [rec]
    assert rec.drop_rate == 0.25 and rec.tokens == 4 and rec.top_k == 2
    # the instrumented record drives the managed capacity re-resolution
    d = managed.resolve_moe_dispatch(
        "model", 8, 1024, 256, 8, 2, 128, capacity_factor=1.0,
        measured_imbalance=rec.imbalance,
        measured_drop_rate=rec.drop_rate)
    assert d.capacity_factor >= rec.imbalance
    instrument.clear_routing_log()
